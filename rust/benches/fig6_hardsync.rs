//! Figure 6: (σ, μ, λ) tradeoff curves for the hardsync protocol —
//! test error vs training time across λ ∈ {1..30}, μ ∈ {4..128}.
//!
//! Claims to preserve (§5.2):
//!  * along μ = 128: time falls monotonically with λ, error rises;
//!  * along λ = 30: shrinking μ restores much of the lost accuracy at
//!    the cost of runtime;
//!  * (0, 4, 1) beats the baseline's error but trains slower.
//!
//! Accuracy from real SGD on the synthetic benchmark; time from the
//! calibrated P775 model on the paper's CIFAR10 geometry.

use rudra::coordinator::protocol::Protocol;
use rudra::harness::paper;
use rudra::harness::sweep::Sweep;
use rudra::harness::Workspace;
use rudra::stats::table::{f, pct, Table};
use rudra::util::fmt_secs;

fn main() {
    paper::banner("Figure 6 — (σ,μ,λ) tradeoff curves, hardsync");
    let ws = Workspace::open_default().expect("run `make artifacts` first");
    let (mus, lambdas, epochs) = paper::grid_axes();
    let mut sweep = Sweep::new(&ws, epochs);
    // grid points run on scoped worker threads (RUDRA_JOBS overrides;
    // 0/unset = available parallelism) — results are bit-identical
    sweep.jobs = rudra::harness::sweep::env_jobs();
    let results = sweep.run_grid(&mus, &lambdas, |_| Protocol::Hardsync).expect("grid");

    let mut t = Table::new(&["μ", "λ", "test err", "sim time (paper geom)", "σ"]);
    for r in &results {
        t.row(vec![
            r.mu.to_string(),
            r.lambda.to_string(),
            pct(r.test_error_pct),
            fmt_secs(r.paper_sim_seconds),
            f(r.avg_staleness, 1),
        ]);
    }
    t.print();
    println!(
        "\npaper baseline (0,128,1): {:.1}% in {} — our (reduced-epoch) runs reproduce the contours' shape",
        paper::CIFAR_BASELINE_ERR,
        fmt_secs(paper::CIFAR_BASELINE_SECS)
    );

    let find = |mu: usize, lambda: usize| {
        results.iter().find(|r| r.mu == mu && r.lambda == lambda).unwrap()
    };
    let max_l = *lambdas.last().unwrap();
    let max_mu = *mus.last().unwrap();
    let min_mu = mus[0];

    // μ=128 contour: time monotone ↓ with λ.
    let mut last = f64::INFINITY;
    for &l in &lambdas {
        let tt = find(max_mu, l).paper_sim_seconds;
        assert!(tt < last, "time must fall with λ at μ={max_mu}: {tt} !< {last}");
        last = tt;
    }
    // error rises along μ=128 from λ=1 to λ=max (within noise).
    let e1 = find(max_mu, 1).test_error_pct;
    let el = find(max_mu, max_l).test_error_pct;
    assert!(el > e1 - 2.0, "scale-out at fixed μ shouldn't reduce error: {e1} -> {el}");
    // λ=max contour: μ=min error ≤ μ=max error (small μ restores accuracy).
    let small = find(min_mu, max_l).test_error_pct;
    let big = find(max_mu, max_l).test_error_pct;
    assert!(
        small <= big + 1.0,
        "shrinking μ should restore accuracy at λ={max_l}: {small} vs {big}"
    );
    // (0, 4, 1) slower than (0, 128, 1).
    assert!(find(min_mu, 1).paper_sim_seconds > find(max_mu, 1).paper_sim_seconds);
    println!("hardsync tradeoff-curve shape reproduced ✓");
}
