//! Figure 5: the learning-rate modulation strategy (α = α₀/⟨σ⟩, Eq. 6).
//!
//! The paper's plot: test error vs epoch for n-softsync at n ∈ {4, 30},
//! λ = 30, μ = 128, with α = α₀ vs α = α₀/n. Headline: the 30-softsync
//! α₀ run fails to converge (stays ~90% = random guessing) while α₀/30
//! converges. Reproduced with real SGD on the synthetic benchmark.

use rudra::config::RunConfig;
use rudra::coordinator::protocol::Protocol;
use rudra::harness::paper;
use rudra::harness::sweep::Sweep;
use rudra::harness::Workspace;
use rudra::params::lr::Modulation;
use rudra::stats::table::{pct, Table};

fn main() {
    paper::banner("Figure 5 — dividing α by ⟨σ⟩ rescues convergence (λ=30, μ=128)");
    let ws = Workspace::open_default().expect("run `make artifacts` first");
    let lambda = 30;
    // μ=128 gradient executions are cheap, so even the reduced run can
    // afford the update count the rescued arm needs to visibly converge
    // (the paper had 140 epochs × 50k samples; we compensate with epochs).
    let epochs = if paper::full_grid() { 90 } else { 60 };
    let mut sweep = Sweep::new(&ws, epochs);
    sweep.eval_each_epoch = true;

    // The synthetic benchmark's stability edge differs from CIFAR10's, so
    // the α₀ arm uses a base LR chosen (like the paper's) to sit at the
    // λ=1 stability edge but beyond it when amplified by ⟨σ⟩ = 30
    // staleness; α₀/30 = 0.01 is inside the known-good range. Plain SGD
    // (no momentum) isolates the staleness effect on the small synthetic
    // budget — with momentum the effective delay grows to σ + m/(1−m) ≈
    // σ+9 and the rescued arm converges too slowly to show in reduced
    // epochs (direction is identical; see EXPERIMENTS.md).
    let base_lr = 0.3;

    let mut t = Table::new(&["config", "modulation", "final test err", "paper behaviour"]);
    let mut finals = std::collections::BTreeMap::new();
    for n in [4usize, 30] {
        for (modulation, label) in
            [(Modulation::None, "α₀"), (Modulation::StalenessReciprocal, "α₀/n")]
        {
            // paper_schedule: the paper's own step-drop recipe (α ×0.1 at
            // ~85% and ~93% of training) — it settles the rescued arm's
            // tail exactly as it settles the paper's Figure 5 curves.
            let cfg = RunConfig {
                protocol: Protocol::NSoftsync { n },
                mu: 128,
                lambda,
                epochs,
                base_lr,
                modulation,
                paper_schedule: true,
                optimizer: rudra::params::optimizer::OptimizerKind::Sgd,
                ..RunConfig::default()
            };
            let p = sweep.run_point(&cfg).expect("sim");
            println!("{n}-softsync {label}: error by epoch (every 5th):");
            for e in &p.epochs {
                if e.epoch % 5 != 0 && e.epoch != 1 {
                    continue;
                }
                if let Some(err) = e.test_error_pct {
                    println!("    epoch {:>2}: {:>6.2}%", e.epoch, err);
                }
            }
            let expected = match (n, modulation) {
                (30, Modulation::None) => "fails to converge (~90%)",
                (_, Modulation::None) => "higher error",
                _ => "converges, lower error",
            };
            t.row(vec![
                format!("{n}-softsync"),
                label.to_string(),
                pct(p.test_error_pct),
                expected.to_string(),
            ]);
            finals.insert((n, label), p.test_error_pct);
        }
    }
    t.print();

    let bad = finals[&(30, "α₀")];
    let good = finals[&(30, "α₀/n")];
    assert!(
        bad > 82.0,
        "30-softsync with unmodulated α should stay near chance (90%): {bad}%"
    );
    assert!(
        good < 80.0 && good < bad - 10.0,
        "α₀/n must rescue convergence: {good}% vs {bad}%"
    );
    let g4 = finals[&(4, "α₀/n")];
    let b4 = finals[&(4, "α₀")];
    assert!(g4 <= b4 + 2.0, "modulation should not hurt at n=4: {g4}% vs {b4}%");
    println!("\nFigure 5's rescue effect reproduced ✓");
}
