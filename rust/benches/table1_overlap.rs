//! Table 1: communication overlap (%) for Rudra-base / adv / adv* in the
//! adversarial scenario — μ = 4 (smallest possible for 4-way learners),
//! 300 MB model, ~60 learners (§3.3).
//!
//! Regenerates the table through the discrete-event cluster model; the
//! paper's metric is compute / (compute + exposed comm) per learner.

use rudra::coordinator::engine_sim::{run_sim, SimConfig};
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::harness::paper;
use rudra::netsim::cost::ModelCost;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::stats::table::{f, Table};

fn overlap_for(arch: Arch, updates: u64) -> f64 {
    // Async (= λ-softsync): the weights timestamp advances on every push,
    // so every cycle moves a model-sized pull — the continuous-traffic
    // regime the adversarial scenario measures.
    let mut cfg = SimConfig::paper(
        Protocol::Async,
        arch,
        4,
        56, // 7 nodes × 8 learners ≈ the paper's "about 60 learners"
        1,
        ModelCost::adversarial_300mb(),
    );
    cfg.max_updates = Some(updates);
    cfg.seed = 1;
    let r = run_sim(
        &cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.01), Modulation::Auto, 128),
        None,
        None,
    )
    .expect("timing sim");
    r.overlap.overlap_pct()
}

fn main() {
    paper::banner("Table 1 — communication overlap (adversarial: μ=4, 300 MB, ~60 learners)");
    let updates = if paper::full_grid() { 400 } else { 60 };
    let mut t = Table::new(&["Implementation", "paper overlap %", "reproduced overlap %"]);
    let mut reproduced = Vec::new();
    for (arch, (name, paper_pct)) in
        [Arch::Base, Arch::Adv, Arch::AdvStar].into_iter().zip(paper::TABLE1_OVERLAP)
    {
        let got = overlap_for(arch, updates);
        reproduced.push(got);
        t.row(vec![name.to_string(), f(paper_pct, 2), f(got, 2)]);
    }
    t.print();
    // the claim to preserve: base ≪ adv ≪ adv*, adv* ≈ full overlap
    assert!(
        reproduced[0] < reproduced[1] && reproduced[1] < reproduced[2],
        "ordering violated: {reproduced:?}"
    );
    assert!(reproduced[2] > 90.0, "adv* should ~fully overlap: {reproduced:?}");
    println!("\nordering base < adv < adv* reproduced ✓");
}
