//! Straggler sweep (manual timing, like `perf_elastic`): slowdown factor
//! × synchronization protocol on the paper's CIFAR10 geometry at λ = 8,
//! timing-only on a zero-jitter cluster so every second is attributable
//! to the straggler model. For each point: simulated epoch time, weight
//! updates, dropped gradients, ⟨σ⟩/max σ, and the utilization spread.
//!
//! Expected shape — the Chen et al. / Dutta et al. tradeoff, live:
//! * hardsync degrades toward the straggler's speed (every barrier round
//!   waits for it);
//! * backup:b closes rounds without the b slowest and recovers ≥ 80% of
//!   the *ideal* (no-straggler) hardsync epoch time even under a 10×
//!   straggler, paying only the smaller per-round quota;
//! * n-softsync absorbs the straggler as staleness (⟨σ⟩ grows with the
//!   skew) rather than wall-clock;
//! * async is fastest and stalest.
//!
//! The tail of the run asserts the acceptance criteria (recovery ≥ 80%,
//! hardsync degradation, and `hetero none` ≡ `slow:0x1` bit-identity),
//! so `cargo bench perf_stragglers` fails loudly on a regression.

use rudra::coordinator::engine_sim::{run_sim, SimConfig, SimResult};
use rudra::coordinator::learner::MockProvider;
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::netsim::cluster::ClusterSpec;
use rudra::netsim::cost::ModelCost;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::stats::table::{f, Table};
use rudra::straggler::hetero::HeteroSpec;
use rudra::util::fmt_secs;

const LAMBDA: usize = 8;
const MU: usize = 128;
const EPOCHS: usize = 2;

fn cfg(protocol: Protocol, hetero: &str) -> SimConfig {
    let mut cfg =
        SimConfig::paper(protocol, Arch::Base, MU, LAMBDA, EPOCHS, ModelCost::cifar10());
    cfg.seed = 29;
    cfg.cluster = ClusterSpec { compute_jitter: 0.0, ..ClusterSpec::p775() };
    cfg.hetero = HeteroSpec::parse(hetero).expect("hetero spec");
    cfg
}

fn run_timing(protocol: Protocol, hetero: &str) -> SimResult {
    run_sim(
        &cfg(protocol, hetero),
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.01), Modulation::Auto, 128),
        None,
        None,
    )
    .expect("timing sim")
}

fn run_numeric(protocol: Protocol, hetero: &str) -> SimResult {
    let mut c = cfg(protocol, hetero);
    c.model = ModelCost {
        name: "tiny",
        flops_per_sample: 1.0e6,
        bytes: 1.0e3,
        samples_per_epoch: 2048,
    };
    let mut provider = MockProvider::new(vec![0.0; 4]);
    run_sim(
        &c,
        FlatVec::from_vec(vec![1.0, -2.0, 0.5, 3.0]),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 4),
        LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
        Some(&mut provider),
        None,
    )
    .expect("numeric sim")
}

fn util_spread(r: &SimResult) -> String {
    let min = r.learner_utilization.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = r.learner_utilization.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    format!("{:.0}–{:.0}%", min * 100.0, max * 100.0)
}

fn main() {
    println!("=== perf_stragglers — slowdown × protocol sweep (timing-only) ===\n");
    println!(
        "CIFAR10 geometry, λ = {LAMBDA}, μ = {MU}, {EPOCHS} epochs, zero jitter;\n\
         `slow:0x<f>` makes learner 0 a persistent f× straggler.\n"
    );

    let protocols = [
        Protocol::Hardsync,
        Protocol::BackupSync { b: 1 },
        Protocol::BackupSync { b: 2 },
        Protocol::NSoftsync { n: 2 },
        Protocol::Async,
    ];
    let scenarios = [("none", "none"), ("3× straggler", "slow:0x3"), ("10× straggler", "slow:0x10")];

    let mut t = Table::new(&[
        "protocol",
        "stragglers",
        "sim time",
        "updates",
        "dropped",
        "⟨σ⟩",
        "max σ",
        "util",
    ]);
    // protocol-major × scenario-minor grid of timing-only points (virtual
    // seconds — host contention cannot perturb them), fanned out over the
    // parallel point executor (RUDRA_JOBS overrides; bit-identical).
    let grid_results = rudra::harness::sweep::run_indexed(
        rudra::harness::sweep::env_jobs(),
        protocols.len() * scenarios.len(),
        |i| {
            let protocol = protocols[i / scenarios.len()];
            let (_, hetero) = scenarios[i % scenarios.len()];
            Ok(run_timing(protocol, hetero))
        },
    )
    .expect("straggler sweep");
    for (i, r) in grid_results.iter().enumerate() {
        let protocol = protocols[i / scenarios.len()];
        let (label, _) = scenarios[i % scenarios.len()];
        t.row(vec![
            protocol.label(),
            label.to_string(),
            fmt_secs(r.sim_seconds),
            r.updates.to_string(),
            r.dropped_gradients.to_string(),
            f(r.staleness.overall_avg(), 2),
            r.staleness.max.to_string(),
            util_spread(r),
        ]);
    }
    t.print();

    // ---- acceptance checks ------------------------------------------------
    // Reuse the grid's own points instead of re-running; look the cells
    // up by (protocol, hetero spec) so reordering the axes cannot
    // silently retarget the assertions.
    let at = |protocol: Protocol, hetero: &str| {
        let pi = protocols
            .iter()
            .position(|&p| p == protocol)
            .expect("protocol swept in the grid");
        let si = scenarios
            .iter()
            .position(|&(_, h)| h == hetero)
            .expect("scenario swept in the grid");
        &grid_results[pi * scenarios.len() + si]
    };
    let ideal = at(Protocol::Hardsync, "none");
    let hard10 = at(Protocol::Hardsync, "slow:0x10");
    let backup10 = at(Protocol::BackupSync { b: 1 }, "slow:0x10");
    let recovery = ideal.sim_seconds / backup10.sim_seconds;
    println!(
        "\n10× single-straggler: ideal hardsync {}, hardsync {} ({:.1}× degraded), \
         backup:1 {} ({:.1}% of ideal pace recovered, {} gradients dropped)",
        fmt_secs(ideal.sim_seconds),
        fmt_secs(hard10.sim_seconds),
        hard10.sim_seconds / ideal.sim_seconds,
        fmt_secs(backup10.sim_seconds),
        recovery * 100.0,
        backup10.dropped_gradients,
    );
    assert!(
        recovery >= 0.8,
        "ACCEPTANCE: backup:1 must recover >= 80% of ideal hardsync epoch time, \
         got {:.1}%",
        recovery * 100.0
    );
    assert!(
        hard10.sim_seconds > 4.0 * ideal.sim_seconds,
        "ACCEPTANCE: hardsync must degrade toward the straggler's speed \
         ({} vs ideal {})",
        fmt_secs(hard10.sim_seconds),
        fmt_secs(ideal.sim_seconds)
    );

    // `hetero none` must preserve bit-identical fixed-seed trajectories:
    // the unit-factor spec exercises the hetero code path and must land
    // on exactly the same virtual seconds, event count, and weights.
    let quiet = run_numeric(Protocol::NSoftsync { n: 2 }, "none");
    let unit = run_numeric(Protocol::NSoftsync { n: 2 }, "slow:0x1");
    assert_eq!(quiet.sim_seconds, unit.sim_seconds, "hetero none must stay bit-identical");
    assert_eq!(quiet.events_processed, unit.events_processed);
    assert_eq!(
        quiet.theta.as_ref().unwrap().data,
        unit.theta.as_ref().unwrap().data,
        "hetero none must not perturb the trajectory"
    );
    println!(
        "bit-identity: hetero none ≡ slow:0x1 ({} events, θ match) — OK",
        quiet.events_processed
    );
}
