//! Elastic-membership sweep (manual timing, like `perf_shards`): churn
//! rates × synchronization protocols on the paper's CIFAR10 geometry at
//! λ = 16, timing-only. For each point: simulated training time, weight
//! updates, churn events, mean recovery time, final λ_active, and the
//! rescaled μ range under the μ·λ = const policy. Expected shape:
//! hardsync pays the most sim-time for churn (every death breaks a
//! barrier round), async the least; recovery keeps λ_active near λ.

use rudra::coordinator::engine_sim::{run_sim, SimConfig, SimResult};
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::elastic::membership::ChurnSchedule;
use rudra::elastic::rescaler::RescalePolicy;
use rudra::netsim::cost::ModelCost;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::stats::table::{f, Table};
use rudra::util::fmt_secs;

fn run_point(protocol: Protocol, kills_per_ksec: f64) -> SimResult {
    let mut cfg = SimConfig::paper(protocol, Arch::Base, 128, 16, 2, ModelCost::cifar10());
    cfg.seed = 23;
    cfg.churn = ChurnSchedule {
        events: Vec::new(),
        kill_rate_per_ksec: kills_per_ksec,
        mean_downtime_secs: 5.0,
    };
    cfg.rescale = RescalePolicy::MuLambdaConst;
    run_sim(
        &cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.01), Modulation::Auto, 128),
        None,
        None,
    )
    .expect("timing sim")
}

fn main() {
    println!("=== perf_elastic — churn rate × protocol sweep (timing-only) ===\n");
    println!(
        "CIFAR10 geometry, λ = 16, μ₀ = 128, 2 epochs, μ·λ = const rescale,\n\
         random kills at the given rate with mean 5 s downtime.\n"
    );

    let mut t = Table::new(&[
        "protocol",
        "kills/ksec",
        "sim time",
        "updates",
        "churn ev",
        "mean recovery",
        "final λ",
        "μ range",
    ]);
    // churn sims report virtual seconds and deterministic per-seed kill
    // sequences, so the 3 × 3 grid fans out over the parallel point
    // executor (RUDRA_JOBS overrides; bit-identical, grid order kept)
    let protocols = [Protocol::Hardsync, Protocol::NSoftsync { n: 1 }, Protocol::Async];
    let rates = [0.0, 25.0, 100.0];
    let results = rudra::harness::sweep::run_indexed(
        rudra::harness::sweep::env_jobs(),
        protocols.len() * rates.len(),
        |i| Ok(run_point(protocols[i / rates.len()], rates[i % rates.len()])),
    )
    .expect("churn sweep");
    for (i, r) in results.iter().enumerate() {
        let protocol = protocols[i / rates.len()];
        let rate = rates[i % rates.len()];
        let mean_rec = if r.recovery_secs.is_empty() {
            "—".to_string()
        } else {
            fmt_secs(rudra::util::mean(&r.recovery_secs))
        };
        let mu_range = if r.rescales.is_empty() {
            "128".to_string()
        } else {
            let lo = r.rescales.iter().map(|x| x.mu).min().unwrap();
            let hi = r.rescales.iter().map(|x| x.mu).max().unwrap();
            format!("{lo}–{hi}")
        };
        t.row(vec![
            protocol.label(),
            f(rate, 0),
            fmt_secs(r.sim_seconds),
            r.updates.to_string(),
            r.churn.len().to_string(),
            mean_rec,
            r.final_active_lambda.to_string(),
            mu_range,
        ]);
    }
    t.print();

    println!(
        "\nsim time should grow with churn rate — steepest under hardsync \
         (a death breaks the barrier round) — while the rescaler holds \
         μ·λ_active ≈ 2048 so the accuracy-governing aggregate batch is \
         unchanged (§5's μ·λ prescription, now live)."
    );
}
