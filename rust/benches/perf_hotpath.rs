//! Hot-path micro-benchmarks (manual timing — criterion is not in the
//! offline vendor set). Measures the L3 components that sit on the
//! per-gradient path, plus the PJRT grad-execution latency per μ, which
//! feeds the §Perf log in EXPERIMENTS.md.

use std::time::Instant;

use rudra::coordinator::protocol::{Accumulator, Protocol};
use rudra::coordinator::server::{ParameterServer, ServerConfig};
use rudra::netsim::event::EventQueue;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::stats::table::Table;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> (String, f64) {
    // warmup
    for _ in 0..iters.min(3) {
        f();
    }
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = start.elapsed().as_secs_f64() / iters as f64;
    (name.to_string(), per)
}

fn main() {
    println!("=== perf_hotpath — L3 micro-benchmarks (manual timing) ===\n");
    let n_params = 24_234; // the synthetic CNN's size
    let big_params = 1_000_000; // ~the LM's order
    let mut rows = Vec::new();

    // 1. PS applyUpdate (axpy) at both model sizes.
    for (label, p) in [("axpy 24k (CNN)", n_params), ("axpy 1M", big_params)] {
        let mut theta = FlatVec::from_vec(vec![0.5; p]);
        let grad = FlatVec::from_vec(vec![0.001; p]);
        rows.push(bench(label, 2000, || theta.axpy(-0.01, &grad)));
    }

    // 2. Momentum and AdaGrad update kernels.
    for (label, kind) in [
        ("momentum update 24k", OptimizerKind::Momentum { momentum: 0.9 }),
        ("adagrad update 24k", OptimizerKind::Adagrad { eps: 1e-8 }),
    ] {
        let mut opt = Optimizer::new(kind, 0.0, n_params);
        let mut theta = FlatVec::from_vec(vec![0.5; n_params]);
        let grad = FlatVec::from_vec(vec![0.001; n_params]);
        rows.push(bench(label, 2000, || opt.apply(&mut theta, &grad, 0.01)));
    }

    // 3. Full server push (accumulate + update under 1-softsync, λ=8).
    {
        let cfg = ServerConfig {
            protocol: Protocol::NSoftsync { n: 8 },
            mu: 4,
            lambda: 8,
            samples_per_epoch: u64::MAX,
            target_epochs: usize::MAX,
            shards: 1,
        };
        let mut server = ParameterServer::new(
            cfg,
            FlatVec::zeros(n_params),
            Optimizer::paper_momentum(n_params),
            LrPolicy::new(Schedule::constant(0.01), Modulation::Auto, 128),
        );
        let grad = FlatVec::from_vec(vec![0.001; n_params]);
        let mut i = 0usize;
        rows.push(bench("server push+update 24k (async)", 2000, || {
            let ts = server.timestamp();
            server.push_gradient(i % 8, &grad, ts).unwrap();
            i += 1;
        }));
    }

    // 4. Accumulator push throughput.
    {
        let mut acc = Accumulator::new(Protocol::NSoftsync { n: 1 }, 1024, n_params);
        let grad = FlatVec::from_vec(vec![0.001; n_params]);
        let mut i = 0usize;
        rows.push(bench("accumulator push 24k", 2000, || {
            acc.push(i % 1024, &grad, 0).unwrap();
            i += 1;
            if acc.ready() {
                let _ = acc.take_update();
            }
        }));
    }

    // 5. Event-queue throughput (the sim engine's backbone).
    {
        let mut q: EventQueue<u32> = EventQueue::new();
        rows.push(bench("event queue push+pop x1000", 500, || {
            for i in 0..1000u32 {
                q.schedule_in((i % 7) as f64 * 0.001, i);
            }
            while q.pop().is_some() {}
        }));
    }

    // 6. Timing-only sim engine: events/second on a 1-epoch CIFAR run.
    {
        use rudra::coordinator::engine_sim::{run_sim, SimConfig};
        use rudra::coordinator::tree::Arch;
        use rudra::netsim::cost::ModelCost;
        let cfg = SimConfig::paper(
            Protocol::NSoftsync { n: 1 },
            Arch::Base,
            16,
            16,
            1,
            ModelCost::cifar10(),
        );
        let start = Instant::now();
        let r = run_sim(
            &cfg,
            FlatVec::zeros(0),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
            LrPolicy::new(Schedule::constant(0.01), Modulation::Auto, 128),
            None,
            None,
        )
        .unwrap();
        let dt = start.elapsed().as_secs_f64();
        println!(
            "sim engine: {} events in {:.3}s = {:.2}M events/s\n",
            r.events_processed,
            dt,
            r.events_processed as f64 / dt / 1e6
        );
    }

    // 7. PJRT grad latency per μ (requires artifacts; skipped otherwise).
    match rudra::harness::Workspace::open_default() {
        Ok(ws) => {
            let theta = ws.cnn_init().unwrap();
            for mu in [4usize, 16, 128] {
                let exec = ws.cnn_grad(mu).unwrap();
                let mut s = rudra::data::sampler::BatchSampler::new(&ws.train, mu, 1, 0);
                let b = s.next_batch();
                rows.push(bench(
                    &format!("PJRT cnn grad μ={mu}"),
                    30,
                    || {
                        let _ = exec.run_images(&theta, &b.images, &b.labels).unwrap();
                    },
                ));
            }
        }
        Err(e) => println!("(skipping PJRT latency rows: {e})"),
    }

    let mut t = Table::new(&["benchmark", "per-iteration"]);
    for (name, per) in &rows {
        t.row(vec![name.clone(), rudra::util::fmt_secs(*per)]);
    }
    t.print();
}
