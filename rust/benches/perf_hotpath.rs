//! Hot-path micro-benchmarks (manual timing — criterion is not in the
//! offline vendor set). Measures the L3 components that sit on the
//! per-gradient path, the sim engine's event throughput, the serial-vs-
//! parallel grid wall time, and the PJRT grad-execution latency per μ.
//!
//! Machine-readable output: every number is also written to
//! `BENCH_hotpath.json` (override the path with `RUDRA_BENCH_JSON`), so
//! the perf trajectory can be compared *across PRs* instead of living in
//! scrollback. CI's `perf-smoke` job runs this bench in quick mode
//! (`RUDRA_QUICK=1` — fewer iterations, a capped grid) and uploads the
//! JSON as a build artifact. Compare two captures with
//! `rudra bench-diff OLD.json NEW.json` ([`rudra::obs::benchdiff`]) —
//! non-zero exit when a kernel regresses past its noise threshold; CI
//! gates on it whenever a prior baseline is available.
//!
//! Acceptance assertion (parallel sweep executor): a 4-point timing-only
//! grid at `jobs = 4` must run ≥ 1.5× faster than `jobs = 1` whenever
//! the host has ≥ 2 cores (skipped on single-core runners), and both
//! grids must agree bit-for-bit.

use std::time::Instant;

use rudra::coordinator::engine_sim::{run_sim, SimConfig, SimResult};
use rudra::coordinator::protocol::{Accumulator, Protocol};
use rudra::coordinator::server::{ParameterServer, ServerConfig};
use rudra::coordinator::tree::Arch;
use rudra::harness::sweep::{default_jobs, run_indexed};
use rudra::netsim::cost::ModelCost;
use rudra::netsim::event::EventQueue;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::stats::table::Table;
use rudra::util::json::Json;

fn quick() -> bool {
    // Strict parse: `RUDRA_QUICK=ture` must abort, not silently run the
    // full-size bench on a CI runner budgeted for the quick one.
    rudra::harness::sweep::env_truthy("RUDRA_QUICK")
}

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> (String, f64) {
    // warmup
    for _ in 0..iters.min(3) {
        f();
    }
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = start.elapsed().as_secs_f64() / iters as f64;
    (name.to_string(), per)
}

/// One grid point for the serial-vs-parallel comparison: timing-only
/// 1-softsync on the ImageNet geometry (a real per-figure workload shape,
/// heavy enough that thread overhead is invisible). All four points are
/// identical by construction so the load balance is perfect and the
/// speedup reflects the executor, not the grid.
fn grid_point() -> SimResult {
    let mut cfg = SimConfig::paper(
        Protocol::NSoftsync { n: 1 },
        Arch::Base,
        16,
        16,
        1,
        ModelCost::imagenet(),
    );
    cfg.seed = 13;
    if quick() {
        cfg.max_updates = Some(1500);
    }
    run_sim(
        &cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.01), Modulation::Auto, 128),
        None,
        None,
    )
    .expect("timing sim")
}

/// Wall-clock seconds for the 4-point grid at the given job count, plus
/// the per-point (sim_seconds, updates, events) for the bit-identity
/// check.
fn grid_wall(jobs: usize) -> (f64, Vec<(f64, u64, u64)>) {
    let start = Instant::now();
    let results = run_indexed(jobs, 4, |_| {
        let r = grid_point();
        Ok((r.sim_seconds, r.updates, r.events_processed))
    })
    .expect("grid");
    (start.elapsed().as_secs_f64(), results)
}

fn main() {
    let quick_mode = quick();
    println!(
        "=== perf_hotpath — L3 micro-benchmarks (manual timing{}) ===\n",
        if quick_mode { ", quick mode" } else { "" }
    );
    let n_params = 24_234; // the synthetic CNN's size
    let big_params = 1_000_000; // ~the LM's order
    let kernel_iters = if quick_mode { 200 } else { 2000 };
    let mut rows = Vec::new();

    // 1. PS applyUpdate (axpy) at both model sizes.
    for (label, p) in [("axpy 24k (CNN)", n_params), ("axpy 1M", big_params)] {
        let mut theta = FlatVec::from_vec(vec![0.5; p]);
        let grad = FlatVec::from_vec(vec![0.001; p]);
        rows.push(bench(label, kernel_iters, || theta.axpy(-0.01, &grad)));
    }

    // 2. Momentum and AdaGrad update kernels.
    for (label, kind) in [
        ("momentum update 24k", OptimizerKind::Momentum { momentum: 0.9 }),
        ("adagrad update 24k", OptimizerKind::Adagrad { eps: 1e-8 }),
    ] {
        let mut opt = Optimizer::new(kind, 0.0, n_params);
        let mut theta = FlatVec::from_vec(vec![0.5; n_params]);
        let grad = FlatVec::from_vec(vec![0.001; n_params]);
        rows.push(bench(label, kernel_iters, || opt.apply(&mut theta, &grad, 0.01)));
    }

    // 3. Full server push (accumulate + update under 1-softsync, λ=8).
    {
        let cfg = ServerConfig {
            protocol: Protocol::NSoftsync { n: 8 },
            mu: 4,
            lambda: 8,
            samples_per_epoch: u64::MAX,
            target_epochs: usize::MAX,
            shards: 1,
        };
        let mut server = ParameterServer::new(
            cfg,
            FlatVec::zeros(n_params),
            Optimizer::paper_momentum(n_params),
            LrPolicy::new(Schedule::constant(0.01), Modulation::Auto, 128),
        );
        let grad = FlatVec::from_vec(vec![0.001; n_params]);
        let mut i = 0usize;
        rows.push(bench("server push+update 24k (async)", kernel_iters, || {
            let ts = server.timestamp();
            server.push_gradient(i % 8, &grad, ts).unwrap();
            i += 1;
        }));
    }

    // 4. Accumulator push throughput (allocation-free drain path).
    {
        let mut acc = Accumulator::new(Protocol::NSoftsync { n: 1 }, 1024, n_params);
        let grad = FlatVec::from_vec(vec![0.001; n_params]);
        let mut avg = FlatVec::zeros(0);
        let mut clock = Vec::new();
        let mut i = 0usize;
        rows.push(bench("accumulator push 24k", kernel_iters, || {
            acc.push(i % 1024, &grad, 0).unwrap();
            i += 1;
            if acc.ready() {
                acc.drain_update(&mut avg, &mut clock);
            }
        }));
    }

    // 5. Event-queue throughput (the sim engine's backbone).
    {
        let mut q: EventQueue<u32> = EventQueue::with_capacity(1000);
        rows.push(bench("event queue push+pop x1000", if quick_mode { 50 } else { 500 }, || {
            for i in 0..1000u32 {
                q.schedule_in((i % 7) as f64 * 0.001, i);
            }
            while q.pop().is_some() {}
        }));
    }

    // 6. Timing-only sim engine: events/second up the λ ladder — the
    // paper's λ = 30 scale, then the datacenter-scale points the event
    // loop must keep interactive (λ = 512 and λ = 4096). 1-softsync
    // ImageNet, one epoch; quick mode caps the update budget (≈15k
    // gradient arrivals per point, 1-softsync folds λ gradients per
    // update) so CI measures per-event cost rather than epoch size.
    let ladder: Vec<(usize, u64, f64)> = [30usize, 512, 4096]
        .into_iter()
        .map(|lambda| {
            let mut cfg = SimConfig::paper(
                Protocol::NSoftsync { n: 1 },
                Arch::Base,
                16,
                lambda,
                1,
                ModelCost::imagenet(),
            );
            cfg.seed = 13;
            if quick_mode {
                cfg.max_updates = Some((15_000 / lambda).max(2) as u64);
            }
            let start = Instant::now();
            let r = run_sim(
                &cfg,
                FlatVec::zeros(0),
                Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
                LrPolicy::new(Schedule::constant(0.01), Modulation::Auto, 128),
                None,
                None,
            )
            .unwrap();
            let dt = start.elapsed().as_secs_f64();
            println!(
                "sim engine λ={lambda:>4}: {} events in {:.3}s = {:.2}M events/s",
                r.events_processed,
                dt,
                r.events_processed as f64 / dt.max(1e-12) / 1e6
            );
            (lambda, r.events_processed, dt)
        })
        .collect();
    println!();

    // 7. Serial vs parallel grid execution (the sweep-executor
    // acceptance measurement): 4 identical timing-only ImageNet points.
    let cores = default_jobs();
    let (serial_secs, serial_points) = grid_wall(1);
    let (parallel_secs, parallel_points) = grid_wall(4);
    let speedup = serial_secs / parallel_secs.max(1e-12);
    assert_eq!(
        serial_points, parallel_points,
        "jobs=4 grid must be bit-identical to jobs=1"
    );
    println!(
        "grid (4 timing-only ImageNet points): jobs=1 {:.3}s, jobs=4 {:.3}s \
         ({speedup:.2}× speedup on {cores} core(s))",
        serial_secs, parallel_secs
    );
    if cores >= 2 {
        assert!(
            speedup >= 1.5,
            "ACCEPTANCE: 4-point grid at jobs=4 must run >= 1.5x faster than \
             jobs=1 on {cores} cores, got {speedup:.2}x"
        );
    } else {
        println!("(single-core runner: skipping the >= 1.5x speedup assertion)");
    }

    // 8. PJRT grad latency per μ (requires artifacts; skipped otherwise).
    match rudra::harness::Workspace::open_default() {
        Ok(ws) => {
            let theta = ws.cnn_init().unwrap();
            for mu in [4usize, 16, 128] {
                let exec = ws.cnn_grad(mu).unwrap();
                let mut s = rudra::data::sampler::BatchSampler::new(&ws.train, mu, 1, 0);
                let b = s.next_batch();
                rows.push(bench(&format!("PJRT cnn grad μ={mu}"), 30, || {
                    let _ = exec.run_images(&theta, &b.images, &b.labels).unwrap();
                }));
            }
        }
        Err(e) => println!("(skipping PJRT latency rows: {e})"),
    }

    let mut t = Table::new(&["benchmark", "per-iteration"]);
    for (name, per) in &rows {
        t.row(vec![name.clone(), rudra::util::fmt_secs(*per)]);
    }
    t.print();

    // 9. The machine-readable baseline (the bench trajectory across PRs).
    let kernels = Json::Obj(
        rows.iter().map(|(name, per)| (name.clone(), Json::num(*per))).collect(),
    );
    let out = Json::obj(vec![
        // schema 2: `sim_engine` became the per-λ ladder (one row per
        // lambda) instead of a single CIFAR point.
        ("schema", Json::num(2.0)),
        ("quick", Json::Bool(quick_mode)),
        ("cores", Json::num(cores as f64)),
        ("kernels_secs_per_iter", kernels),
        (
            "sim_engine",
            Json::Arr(
                ladder
                    .iter()
                    .map(|&(lambda, events, wall)| {
                        Json::obj(vec![
                            ("lambda", Json::num(lambda as f64)),
                            ("events", Json::num(events as f64)),
                            ("wall_secs", Json::num(wall)),
                            ("events_per_sec", Json::num(events as f64 / wall.max(1e-12))),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "grid",
            Json::obj(vec![
                ("points", Json::num(4.0)),
                ("jobs", Json::num(4.0)),
                ("serial_secs", Json::num(serial_secs)),
                ("parallel_secs", Json::num(parallel_secs)),
                ("speedup", Json::num(speedup)),
            ]),
        ),
    ]);
    let path = std::env::var("RUDRA_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    std::fs::write(&path, out.to_string()).expect("writing bench JSON");
    println!("\nwrote machine-readable baselines to {path}");
}
