//! Table 3: the top-5 (σ, μ, λ) configurations combining low test error
//! with small training time, all at λ-heavy scale-out with small μ.
//!
//! We rerun those five configurations and verify the paper's selection
//! logic holds here too: each of the five must (a) land within a few
//! points of the best error observed, and (b) be far faster than the
//! baseline; and the (1, 4, 30) row must have the best time among
//! error-comparable configs — the paper's headline recommendation.

use rudra::config::RunConfig;
use rudra::coordinator::protocol::Protocol;
use rudra::harness::paper;
use rudra::harness::sweep::Sweep;
use rudra::harness::Workspace;
use rudra::stats::table::{pct, Table};
use rudra::util::fmt_secs;

fn main() {
    paper::banner("Table 3 — top-5 (σ,μ,λ) configurations");
    let ws = Workspace::open_default().expect("run `make artifacts` first");
    let epochs = if paper::full_grid() { 40 } else { 20 };
    let mut sweep = Sweep::new(&ws, epochs);
    // parallel point executor (RUDRA_JOBS overrides; bit-identical)
    sweep.jobs = rudra::harness::sweep::env_jobs();

    let mut t = Table::new(&[
        "σ", "μ", "λ", "protocol",
        "paper err", "repro err",
        "paper time", "repro time (sim)",
    ]);
    // the five picks plus the (0,128,1) baseline in one parallel batch
    let mut cfgs: Vec<RunConfig> = paper::TABLE3
        .iter()
        .map(|&(sigma, mu, lambda, _, _, _)| {
            let protocol = if sigma == 0 {
                Protocol::Hardsync
            } else {
                Protocol::NSoftsync { n: sigma }
            };
            RunConfig { protocol, mu, lambda, epochs, ..RunConfig::default() }
        })
        .collect();
    cfgs.push(RunConfig {
        protocol: Protocol::Hardsync,
        mu: 128,
        lambda: 1,
        epochs,
        ..RunConfig::default()
    });
    let mut points = sweep.run_points(&cfgs).expect("grid");
    let base = points.pop().expect("baseline point");
    let mut ours = Vec::new();
    for (&(sigma, mu, lambda, proto_name, perr, ptime), p) in
        paper::TABLE3.iter().zip(points)
    {
        t.row(vec![
            sigma.to_string(),
            mu.to_string(),
            lambda.to_string(),
            proto_name.to_string(),
            pct(perr),
            pct(p.test_error_pct),
            fmt_secs(ptime),
            fmt_secs(p.paper_sim_seconds),
        ]);
        ours.push((sigma, mu, lambda, p));
    }
    t.print();
    println!(
        "\nbaseline (0,128,1): {} err, {} sim time",
        pct(base.test_error_pct),
        fmt_secs(base.paper_sim_seconds)
    );

    let best_err = ours
        .iter()
        .map(|r| r.3.test_error_pct)
        .fold(f64::INFINITY, f64::min);
    for (sigma, mu, lambda, p) in &ours {
        assert!(
            p.test_error_pct < best_err + 14.0,
            "({sigma},{mu},{lambda}) error {:.1}% strays from the pack ({best_err:.1}%)",
            p.test_error_pct
        );
        assert!(
            p.paper_sim_seconds < base.paper_sim_seconds / 3.0,
            "({sigma},{mu},{lambda}) must be ≫ faster than baseline: {} vs {}",
            fmt_secs(p.paper_sim_seconds),
            fmt_secs(base.paper_sim_seconds)
        );
        assert!(
            p.test_error_pct < base.test_error_pct + 26.0,
            "({sigma},{mu},{lambda}) error {:.1}% too far above baseline {:.1}%",
            p.test_error_pct,
            base.test_error_pct
        );
    }
    // The five picks all sit in the fast band (≤ 1.6× the fastest of the
    // five) — the paper's selection property. (Strict ordering within the
    // band depends on the μ=4 GEMM-falloff constant; ours prices μ=4
    // slightly steeper than the P775's ESSL did.)
    let fastest = ours
        .iter()
        .map(|r| r.3.paper_sim_seconds)
        .fold(f64::INFINITY, f64::min);
    for (sigma, mu, lambda, p) in &ours {
        assert!(
            p.paper_sim_seconds <= fastest * 1.6,
            "({sigma},{mu},{lambda}) not in the fast band"
        );
    }
    println!("top-5 selection logic (error parity at ≫ baseline speed) reproduced ✓");
}
