//! Communication sweep: codec × root shards × architecture/protocol on
//! the Table 1 adversarial workload (300 MB model, λ = 32, Rudra-base
//! flat push vs shard-striped Adv\*). Reports simulated time plus root
//! bytes-on-wire per weight update, and asserts the PR 4 acceptance
//! criterion: `topk:0.01` + the shard-striped Adv\* broadcast cut
//! simulated root bytes ≥ 10× vs the flat uncompressed push at S = 4.
//!
//! Manual timing bench (like `perf_shards`): run with
//! `cargo bench --bench perf_comm`.

use rudra::comm::codec::CodecSpec;
use rudra::coordinator::engine_sim::{run_sim, SimConfig, SimResult};
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::netsim::cost::ModelCost;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::stats::table::{f, Table};
use rudra::util::{fmt_bytes, fmt_secs};

const LAMBDA: usize = 32;
const MAX_UPDATES: u64 = 30;

fn run_point(arch: Arch, shards: usize, compress: &str, protocol: Protocol) -> SimResult {
    let mut cfg = SimConfig::paper(
        protocol,
        arch,
        4,
        LAMBDA,
        1,
        ModelCost::adversarial_300mb(),
    );
    cfg.seed = 5;
    cfg.shards = shards;
    cfg.max_updates = Some(MAX_UPDATES);
    cfg.compress = CodecSpec::parse(compress).expect("codec spec");
    run_sim(
        &cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.001), Modulation::Auto, 128),
        None,
        None,
    )
    .expect("timing sim")
}

fn root_bytes_per_update(r: &SimResult) -> f64 {
    (r.root_bytes_in + r.root_bytes_out) / r.updates.max(1) as f64
}

fn main() {
    println!(
        "=== perf_comm — codec × shards × protocol sweep \
         (Table 1 adversarial model, λ = {LAMBDA}) ===\n"
    );

    let mut t = Table::new(&[
        "codec",
        "arch",
        "S",
        "protocol",
        "sim time",
        "root B/update",
        "vs flat dense ×",
    ]);
    // Every point (baseline first) reports virtual seconds and byte
    // counters, so the sweep fans out over the parallel point executor
    // (RUDRA_JOBS overrides; results land in grid order, bit-identical).
    let grid = [
        // the flat uncompressed push at S = 4: the acceptance baseline
        ("none", Arch::Base, 4, Protocol::NSoftsync { n: 1 }),
        ("none", Arch::Base, 1, Protocol::NSoftsync { n: 1 }),
        ("qsgd:4", Arch::Base, 4, Protocol::NSoftsync { n: 1 }),
        ("topk:0.01", Arch::Base, 4, Protocol::NSoftsync { n: 1 }),
        ("none", Arch::AdvStar, 4, Protocol::NSoftsync { n: 1 }),
        ("topk:0.01", Arch::AdvStar, 1, Protocol::NSoftsync { n: 1 }),
        ("topk:0.01", Arch::AdvStar, 4, Protocol::NSoftsync { n: 1 }),
        ("topk:0.01", Arch::AdvStar, 4, Protocol::NSoftsync { n: 4 }),
        ("qsgd:4", Arch::Base, 4, Protocol::Hardsync),
        ("topk:0.01", Arch::Base, 4, Protocol::Hardsync),
    ];
    let results = rudra::harness::sweep::run_indexed(
        rudra::harness::sweep::env_jobs(),
        grid.len(),
        |i| {
            let (codec, arch, shards, protocol) = grid[i];
            Ok(run_point(arch, shards, codec, protocol))
        },
    )
    .expect("codec sweep");
    let base_bpu = root_bytes_per_update(&results[0]);

    let mut accept: Option<f64> = None;
    for (&(codec, arch, shards, protocol), r) in grid.iter().zip(results.iter()) {
        let bpu = root_bytes_per_update(r);
        if codec == "topk:0.01"
            && arch == Arch::AdvStar
            && shards == 4
            && protocol == (Protocol::NSoftsync { n: 1 })
        {
            accept = Some(base_bpu / bpu);
        }
        t.row(vec![
            codec.to_string(),
            arch.label().to_string(),
            shards.to_string(),
            protocol.label(),
            fmt_secs(r.sim_seconds),
            fmt_bytes(bpu),
            f(base_bpu / bpu, 1),
        ]);
    }
    t.print();

    let reduction = accept.expect("acceptance configuration swept");
    println!(
        "\nbaseline (flat dense push, S=4): {} root bytes/update",
        fmt_bytes(base_bpu)
    );
    println!(
        "topk:0.01 + shard-striped Adv* broadcast at S=4: {reduction:.1}× fewer root \
         bytes-on-wire (acceptance floor: 10×)"
    );
    assert!(
        reduction >= 10.0,
        "acceptance criterion failed: {reduction:.1}× < 10×"
    );
}
