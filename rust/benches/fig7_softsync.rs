//! Figure 7: (σ, μ, λ) tradeoff curves for (a) λ-softsync and
//! (b) 1-softsync.
//!
//! Claims to preserve (§5.2):
//!  * curves look qualitatively like hardsync's, but the error penalty at
//!    (σ,μ,λ) = (30,128,30) is *more* pronounced than hardsync's;
//!  * the μ=4 contour keeps error near baseline for any staleness — the
//!    "small mini-batch confers immunity to stale gradients" finding;
//!  * λ-softsync's (30,4,30) pays a sharp runtime penalty vs (30,128,30);
//!    1-softsync avoids the μ=4 runtime collapse (reduced pull traffic).

use rudra::coordinator::protocol::Protocol;
use rudra::harness::paper;
use rudra::harness::sweep::Sweep;
use rudra::harness::Workspace;
use rudra::stats::table::{f, pct, Table};
use rudra::util::fmt_secs;

fn main() {
    paper::banner("Figure 7 — (σ,μ,λ) tradeoff curves, λ-softsync and 1-softsync");
    let ws = Workspace::open_default().expect("run `make artifacts` first");
    let (mus, lambdas, epochs) = paper::grid_axes();

    let families: [(&str, fn(usize) -> Protocol); 2] = [
        ("λ-softsync", |l| Protocol::NSoftsync { n: l }),
        ("1-softsync", |_| Protocol::NSoftsync { n: 1 }),
    ];
    for (name, proto_of) in families {
        println!("--- Figure 7 ({name}) ---");
        let mut sweep = Sweep::new(&ws, epochs);
        // parallel point executor (RUDRA_JOBS overrides; bit-identical)
        sweep.jobs = rudra::harness::sweep::env_jobs();
        let results = sweep.run_grid(&mus, &lambdas, proto_of).expect("grid");
        let mut t = Table::new(&["μ", "λ", "⟨σ⟩", "test err", "sim time (paper geom)"]);
        for r in &results {
            t.row(vec![
                r.mu.to_string(),
                r.lambda.to_string(),
                f(r.avg_staleness, 1),
                pct(r.test_error_pct),
                fmt_secs(r.paper_sim_seconds),
            ]);
        }
        t.print();

        let find = |mu: usize, lambda: usize| {
            results.iter().find(|r| r.mu == mu && r.lambda == lambda).unwrap()
        };
        let max_l = *lambdas.last().unwrap();
        let max_mu = *mus.last().unwrap();
        let min_mu = mus[0];
        // μ=4 immunity: error at (min_mu, max_l) within a few points of
        // (min_mu, 1) despite the staleness.
        let e_small_scaled = find(min_mu, max_l).test_error_pct;
        let e_small_base = find(min_mu, 1).test_error_pct;
        assert!(
            e_small_scaled < e_small_base + 8.0,
            "{name}: μ={min_mu} contour should stay near baseline: {e_small_scaled} vs {e_small_base}"
        );
        // big-μ degradation exists at scale
        let e_big_scaled = find(max_mu, max_l).test_error_pct;
        assert!(
            e_big_scaled >= e_small_scaled - 2.0,
            "{name}: large μ at λ={max_l} should not beat small μ: {e_big_scaled} vs {e_small_scaled}"
        );
        println!();
    }

    // Runtime distinction at μ=4, λ=max: λ-softsync pays for PS traffic,
    // 1-softsync doesn't (Fig 7's (30,4,30) spike).
    let (mus, lambdas, _) = paper::grid_axes();
    let min_mu = mus[0];
    let max_l = *lambdas.last().unwrap();
    let mut sweep = Sweep::new(&ws, 1);
    sweep.jobs = rudra::harness::sweep::env_jobs();
    let t_lambda = sweep
        .run_grid(&[min_mu], &[max_l], |l| Protocol::NSoftsync { n: l })
        .unwrap()[0]
        .paper_sim_seconds;
    let t_one = sweep
        .run_grid(&[min_mu], &[max_l], |_| Protocol::NSoftsync { n: 1 })
        .unwrap()[0]
        .paper_sim_seconds;
    println!(
        "runtime at (μ={min_mu}, λ={max_l}): λ-softsync {} vs 1-softsync {}",
        fmt_secs(t_lambda),
        fmt_secs(t_one)
    );
    assert!(
        t_one <= t_lambda * 1.05,
        "1-softsync should not be slower: {t_one} vs {t_lambda}"
    );
    println!("\nsoftsync tradeoff-curve shape reproduced ✓");
}
