//! Figure 9: validation error vs (simulated) training wall-clock for the
//! four Table-4 configurations. The paper's reading: training speed
//! orders adv*-softsync > adv-softsync > base-softsync > base-hardsync,
//! so adv*-softsync reaches the 48%-error mark first even though its
//! final error is marginally higher.
//!
//! We emit each configuration's (time, error) series: the error series
//! from real SGD on the synthetic benchmark (matched protocol/arch), the
//! time base scaled by the simulated paper-geometry epoch time.

use rudra::config::RunConfig;
use rudra::coordinator::engine_sim::{run_sim, SimConfig};
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::harness::paper;
use rudra::harness::sweep::Sweep;
use rudra::harness::Workspace;
use rudra::netsim::cost::ModelCost;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;

fn paper_epoch_minutes(arch: Arch, protocol: Protocol, mu: usize, lambda: usize) -> f64 {
    let mut cfg = SimConfig::paper(protocol, arch, mu, lambda, 1, ModelCost::imagenet());
    cfg.seed = 2;
    run_sim(
        &cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.01), Modulation::Auto, 128),
        None,
        None,
    )
    .expect("timing")
    .sim_seconds
        / 60.0
}

fn main() {
    paper::banner("Figure 9 — validation error vs training time, Table-4 configs");
    let ws = Workspace::open_default().expect("run `make artifacts` first");
    let epochs = if paper::full_grid() { 10 } else { 4 };

    let mut series = Vec::new();
    for &(name, arch_s, mu, lambda, proto_s, _t1, _t5, _pmin) in paper::TABLE4.iter() {
        let arch = Arch::parse(arch_s).unwrap();
        let protocol = Protocol::parse(proto_s).unwrap();
        let minutes_per_epoch = paper_epoch_minutes(arch, protocol, mu, lambda);

        let mut sweep = Sweep::new(&ws, epochs);
        sweep.arch = arch;
        sweep.eval_each_epoch = true;
        let cfg = RunConfig {
            protocol,
            mu: mu.min(16),
            lambda: lambda.min(30),
            epochs,
            warmstart_epochs: if protocol != Protocol::Hardsync { 1 } else { 0 },
            ..RunConfig::default()
        };
        let p = sweep.run_point(&cfg).expect("point");
        let pts: Vec<(f64, f64)> = p
            .epochs
            .iter()
            .filter_map(|e| e.test_error_pct.map(|er| (e.epoch as f64 * minutes_per_epoch, er)))
            .collect();
        println!("{name} ({:.0} sim-min/epoch):", minutes_per_epoch);
        for (t, er) in &pts {
            println!("    t = {t:>8.0} min   err = {er:>6.2}%");
        }
        series.push((name, minutes_per_epoch, pts));
    }

    // Time-to-target: the architecture ladder must order the time at
    // which each config crosses a common error threshold.
    let threshold = series
        .iter()
        .filter_map(|(_, _, pts)| pts.iter().map(|p| p.1).fold(None, |a: Option<f64>, b| {
            Some(a.map_or(b, |x| x.min(b)))
        }))
        .fold(0.0f64, f64::max)
        + 2.0; // reachable by every config
    let cross = |pts: &[(f64, f64)]| {
        pts.iter().find(|(_, e)| *e <= threshold).map(|(t, _)| *t).unwrap_or(f64::INFINITY)
    };
    let t_first = cross(&series[0].2);
    let t_last = cross(&series[3].2);
    println!(
        "\ntime to {threshold:.1}% error: {} = {:.0} min, {} = {:.0} min",
        series[0].0, t_first, series[3].0, t_last
    );
    assert!(
        t_last < t_first,
        "adv*-softsync must reach the common error mark first ({t_last} !< {t_first})"
    );
    // Per-epoch speed ordering matches the paper's reading.
    for w in series.windows(2) {
        assert!(
            w[1].1 < w[0].1,
            "{} should train faster per epoch than {}",
            w[1].0,
            w[0].0
        );
    }
    println!("training-speed ordering adv* > adv > base-softsync > base-hardsync reproduced ✓");
}
