//! Table 4: the ImageNet ladder — base-hardsync, base-softsync,
//! adv-softsync, adv*-softsync — validation error vs minutes/epoch.
//!
//! Times come from the discrete-event P775 model at the paper's exact
//! workload geometry (289 MB AlexNet, 1.2M images/epoch, the paper's
//! (μ, λ) pairs). Accuracy *ordering* is validated at reduced scale on
//! the synthetic benchmark with matched (protocol, arch, σ) — per the
//! substitution table in DESIGN.md §3 (repro band 0: no ImageNet here).

use rudra::config::RunConfig;
use rudra::coordinator::engine_sim::{run_sim, SimConfig};
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::harness::paper;
use rudra::harness::sweep::Sweep;
use rudra::harness::Workspace;
use rudra::netsim::cost::ModelCost;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::stats::table::{f, pct, Table};

fn epoch_minutes(arch: Arch, protocol: Protocol, mu: usize, lambda: usize) -> f64 {
    let mut cfg =
        SimConfig::paper(protocol, arch, mu, lambda, 1, ModelCost::imagenet());
    cfg.seed = 2;
    let r = run_sim(
        &cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.01), Modulation::Auto, 128),
        None,
        None,
    )
    .expect("timing sim");
    r.sim_seconds / 60.0
}

fn main() {
    paper::banner("Table 4 — ImageNet ladder (time simulated at paper geometry)");
    let ws = Workspace::open_default().expect("run `make artifacts` first");

    let mut t = Table::new(&[
        "config", "arch", "μ", "λ", "protocol",
        "paper min/epoch", "repro min/epoch (sim)",
        "paper top-1", "repro err (synthetic)",
    ]);
    let epochs = if paper::full_grid() { 10 } else { 4 };
    // Each ladder rung (timing sim at paper geometry + reduced-scale
    // accuracy point) is index-determined, so the whole ladder runs on
    // the parallel point executor (RUDRA_JOBS overrides; bit-identical).
    let rungs = rudra::harness::sweep::run_indexed(
        rudra::harness::sweep::env_jobs(),
        paper::TABLE4.len(),
        |i| {
            let (_, arch_s, mu, lambda, proto_s, _, _, _) = paper::TABLE4[i];
            let arch = rudra::coordinator::tree::Arch::parse(arch_s)?;
            let protocol = Protocol::parse(proto_s)?;
            let minutes = epoch_minutes(arch, protocol, mu, lambda);

            // Accuracy ordering at reduced scale: same protocol/arch
            // family, λ capped to the synthetic benchmark's range.
            let mut sweep = Sweep::new(&ws, epochs);
            sweep.arch = arch;
            sweep.jobs = 1; // already inside a worker thread
            let cfg = RunConfig {
                protocol,
                mu: mu.min(16),
                lambda: lambda.min(30),
                epochs,
                warmstart_epochs: if protocol != Protocol::Hardsync { 1 } else { 0 },
                optimizer: if protocol != Protocol::Hardsync {
                    rudra::params::optimizer::OptimizerKind::Adagrad { eps: 1e-8 }
                } else {
                    rudra::params::optimizer::OptimizerKind::Momentum { momentum: 0.9 }
                },
                base_lr: if protocol != Protocol::Hardsync { 0.03 } else { 0.02 },
                ..RunConfig::default()
            };
            let p = sweep.run_point(&cfg)?;
            Ok((minutes, p))
        },
    )
    .expect("ladder");
    let mut times = Vec::new();
    let mut errs = Vec::new();
    for (&(name, arch_s, mu, lambda, proto_s, top1, _top5, pmin), (minutes, p)) in
        paper::TABLE4.iter().zip(rungs)
    {
        t.row(vec![
            name.to_string(),
            arch_s.to_string(),
            mu.to_string(),
            lambda.to_string(),
            proto_s.to_string(),
            f(pmin, 0),
            f(minutes, 0),
            pct(top1),
            pct(p.test_error_pct),
        ]);
        times.push((name, minutes, pmin));
        errs.push((name, p.test_error_pct));
    }
    t.print();

    // Claim 1: the runtime ladder strictly improves down the table.
    for w in times.windows(2) {
        assert!(
            w[1].1 < w[0].1,
            "{} ({:.0}) should be faster than {} ({:.0})",
            w[1].0,
            w[1].1,
            w[0].0,
            w[0].1
        );
    }
    // Claim 2: each simulated time is within 2× of the paper's.
    for (name, got, want) in &times {
        let ratio = got / want;
        assert!(
            (0.5..2.0).contains(&ratio),
            "{name}: simulated {got:.0} min/epoch vs paper {want:.0} (×{ratio:.2})"
        );
    }
    // Claim 3: hardsync's accuracy is the best of the ladder (paper:
    // 44.35% top-1 vs 45.6/46.1/46.5 for the softsync rungs).
    let hard_err = errs[0].1;
    for (name, e) in &errs[1..] {
        assert!(
            *e >= hard_err - 3.0,
            "{name} ({e:.1}%) should not beat hardsync ({hard_err:.1}%) materially"
        );
    }
    println!("\nladder: runtime strictly improves base→adv*, hardsync most accurate ✓");
}
