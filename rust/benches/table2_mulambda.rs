//! Table 2: μλ = constant ⇒ comparable test error, nearly independent of
//! staleness; error grows monotonically with the μλ product; 1-softsync
//! shows the smallest training time within each group (§5.3).
//!
//! Accuracy from real SGD on the synthetic benchmark; times from the
//! calibrated P775 model on the paper's CIFAR10 geometry. Paper rows are
//! printed alongside for every configuration we run.

use rudra::config::RunConfig;
use rudra::coordinator::protocol::Protocol;
use rudra::harness::paper;
use rudra::harness::sweep::Sweep;
use rudra::harness::Workspace;
use rudra::stats::table::{pct, Table};
use rudra::util::fmt_secs;

/// A Table-2 configuration: (σ, μ, λ) with σ = softsync n (0 = hardsync).
fn protocol_of(sigma: usize) -> Protocol {
    if sigma == 0 {
        Protocol::Hardsync
    } else {
        Protocol::NSoftsync { n: sigma }
    }
}

fn main() {
    paper::banner("Table 2 — μλ = constant configurations");
    let ws = Workspace::open_default().expect("run `make artifacts` first");
    // Within-group comparability is a near-convergence property (the
    // paper trains 140 epochs); undertrained runs separate by update
    // count instead, so the reduced run still needs a real budget.
    let epochs = if paper::full_grid() { 40 } else { 20 };
    let mut sweep = Sweep::new(&ws, epochs);
    // parallel point executor (RUDRA_JOBS overrides; bit-identical)
    sweep.jobs = rudra::harness::sweep::env_jobs();

    // Representative subset per μλ group (full = every paper row).
    let rows: Vec<(usize, usize, usize, f64, f64)> = if paper::full_grid() {
        paper::TABLE2.to_vec()
    } else {
        vec![
            // (σ, μ, λ, paper err %, paper time s)
            (1, 4, 30, 18.09, 1573.0),
            (30, 4, 30, 18.41, 2073.0),
            (2, 64, 2, 17.96, 13449.0),
            (1, 8, 30, 20.04, 1478.0),
            (10, 32, 10, 20.82, 3518.0),
            (1, 16, 30, 23.25, 1469.0),
            (1, 32, 30, 27.16, 1299.0),
            (18, 64, 18, 28.31, 1713.0),
        ]
    };

    let mut t = Table::new(&[
        "μλ", "σ", "μ", "λ",
        "paper err", "repro err",
        "paper time", "repro time (sim)",
    ]);
    let mut by_group: std::collections::BTreeMap<usize, Vec<f64>> = Default::default();
    let mut results = Vec::new();
    // one parallel batch over every Table-2 row, results in row order
    let cfgs: Vec<RunConfig> = rows
        .iter()
        .map(|&(sigma, mu, lambda, _, _)| RunConfig {
            protocol: protocol_of(sigma),
            mu,
            lambda,
            epochs,
            ..RunConfig::default()
        })
        .collect();
    let points = sweep.run_points(&cfgs).expect("grid");
    for (&(sigma, mu, lambda, perr, ptime), p) in rows.iter().zip(points) {
        // nearest group anchor by ratio distance (μλ=1152 → 1024, not 2048)
        let group = *[128usize, 256, 512, 1024]
            .iter()
            .min_by(|&&a, &&b| {
                let ra = (mu * lambda) as f64 / a as f64;
                let rb = (mu * lambda) as f64 / b as f64;
                ra.max(1.0 / ra).partial_cmp(&rb.max(1.0 / rb)).unwrap()
            })
            .unwrap();
        by_group.entry(group).or_default().push(p.test_error_pct);
        t.row(vec![
            format!("≈{group}"),
            sigma.to_string(),
            mu.to_string(),
            lambda.to_string(),
            pct(perr),
            pct(p.test_error_pct),
            fmt_secs(ptime),
            fmt_secs(p.paper_sim_seconds),
        ]);
        results.push((group, sigma, mu, lambda, p));
    }
    t.print();

    // Claim 1: within a μλ group, error is comparable across σ.
    for (group, errs) in &by_group {
        if errs.len() < 2 {
            continue;
        }
        let min = errs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = errs.iter().cloned().fold(0.0, f64::max);
        println!("μλ≈{group}: error spread {:.2}–{:.2}%", min, max);
        assert!(
            max - min < 15.0,
            "μλ≈{group}: error should be comparable across σ, spread {}",
            max - min
        );
    }
    // Claim 2: group means increase with μλ.
    let means: Vec<(usize, f64)> = by_group
        .iter()
        .map(|(g, e)| (*g, e.iter().sum::<f64>() / e.len() as f64))
        .collect();
    for w in means.windows(2) {
        assert!(
            w[1].1 > w[0].1 - 2.0,
            "error should rise with μλ: {:?} -> {:?}",
            w[0],
            w[1]
        );
    }
    let first = means.first().unwrap().1;
    let last = means.last().unwrap().1;
    assert!(last > first + 2.0, "μλ error growth not visible: {first} -> {last}");
    // Claim 3: within groups containing a 1-softsync row at high λ, it
    // sits in the group's fast band (the paper: smallest time per group;
    // our cost model prices the μ=4 GEMM falloff slightly differently,
    // so assert "within 25% of the group's fastest" rather than strictly
    // first).
    for (group, _) in &by_group {
        let in_group: Vec<_> = results.iter().filter(|r| r.0 == *group).collect();
        if let Some(soft1) = in_group.iter().find(|r| r.1 == 1) {
            let fastest = in_group
                .iter()
                .map(|r| r.4.paper_sim_seconds)
                .fold(f64::INFINITY, f64::min);
            assert!(
                soft1.4.paper_sim_seconds <= fastest * 1.25,
                "μλ≈{group}: 1-softsync should be in the fast band"
            );
        }
    }
    println!("\nμλ=constant error equivalence + monotone growth + 1-softsync fast band reproduced ✓");
}
