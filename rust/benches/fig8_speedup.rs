//! Figure 8: speed-up in training time vs λ for (a) μ=128 and (b) μ=4,
//! under hardsync, λ-softsync, and 1-softsync.
//!
//! Claims to preserve (§5.2): at μ=128 the two softsyncs track each other
//! and beat hardsync; at μ=4 λ-softsync's speed-up is subdued relative to
//! 1-softsync (PS traffic), and hardsync fares worst in both.
//! Speed-ups are relative to (0, μ, 1) like the paper's.

use rudra::coordinator::engine_sim::{run_sim, SimConfig};
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::harness::paper;
use rudra::netsim::cost::ModelCost;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::stats::table::{f, Table};

fn time_for(protocol: Protocol, mu: usize, lambda: usize, epochs: usize) -> f64 {
    let mut cfg =
        SimConfig::paper(protocol, Arch::Base, mu, lambda, epochs, ModelCost::cifar10());
    cfg.seed = 11;
    run_sim(
        &cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.001), Modulation::Auto, 128),
        None,
        None,
    )
    .expect("timing sim")
    .sim_seconds
}

fn main() {
    paper::banner("Figure 8 — speed-up vs λ at μ=128 and μ=4 (CIFAR10 geometry)");
    let lambdas: Vec<usize> =
        if paper::full_grid() { vec![1, 2, 4, 10, 18, 30] } else { vec![1, 4, 10, 30] };
    let epochs = if paper::full_grid() { 4 } else { 1 };

    for mu in [128usize, 4] {
        println!("--- Fig 8({}) μ = {mu} ---", if mu == 128 { "a" } else { "b" });
        let base = time_for(Protocol::NSoftsync { n: 1 }, mu, 1, epochs);
        let mut t =
            Table::new(&["λ", "hardsync ×", "λ-softsync ×", "1-softsync ×"]);
        let mut rows = Vec::new();
        for &l in &lambdas {
            let s_hard = base / time_for(Protocol::Hardsync, mu, l, epochs);
            let s_lsoft = base / time_for(Protocol::NSoftsync { n: l }, mu, l, epochs);
            let s_1soft = base / time_for(Protocol::NSoftsync { n: 1 }, mu, l, epochs);
            t.row(vec![l.to_string(), f(s_hard, 2), f(s_lsoft, 2), f(s_1soft, 2)]);
            rows.push((l, s_hard, s_lsoft, s_1soft));
        }
        t.print();

        let (_, h, ls, os) = *rows.last().unwrap();
        assert!(os >= h, "μ={mu}: 1-softsync ({os:.2}) should beat hardsync ({h:.2})");
        assert!(ls >= h * 0.9, "μ={mu}: λ-softsync should not trail hardsync badly");
        if mu == 4 {
            assert!(
                os >= ls * 0.98,
                "μ=4: 1-softsync ({os:.2}) should be at least λ-softsync ({ls:.2})"
            );
        }
        // scale-out is material at the largest λ
        let max_l = *lambdas.last().unwrap() as f64;
        assert!(os > max_l * 0.3, "μ={mu}: speed-up {os:.2} too small for λ={max_l}");
        println!();
    }
    println!("speed-up ordering (hardsync worst; softsyncs comparable; μ=4 penalty) reproduced ✓");
}
