//! Shard-count sweep over the hotpath workload (manual timing, like
//! `perf_hotpath`): measures the server-side push+applyUpdate wall time
//! at S ∈ {1, 2, 4, 8} on a 1M-parameter model, plus the simulated-time
//! relief on the §3.3 adversarial workload where the flat root is the
//! bottleneck. Expected shape: per-push wall time and adversarial
//! sim-time both decrease as S grows.

use std::time::Instant;

use rudra::coordinator::engine_sim::{run_sim, SimConfig};
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::server::ServerConfig;
use rudra::coordinator::shard::ShardedServer;
use rudra::coordinator::tree::Arch;
use rudra::netsim::cost::ModelCost;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::stats::table::{f, Table};

/// Seconds per push (each push triggers applyUpdate under async) on a
/// `ShardedServer` with `shards` shards over `n_params` weights.
fn bench_server_push(n_params: usize, shards: usize, iters: usize) -> f64 {
    let cfg = ServerConfig {
        protocol: Protocol::Async,
        mu: 4,
        lambda: 8,
        samples_per_epoch: u64::MAX,
        target_epochs: usize::MAX,
        shards,
    };
    let mut server = ShardedServer::new(
        cfg,
        FlatVec::zeros(n_params),
        Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, n_params),
        LrPolicy::new(Schedule::constant(0.01), Modulation::Auto, 128),
    );
    let grad = FlatVec::from_vec(vec![0.001; n_params]);
    // warmup
    for i in 0..8usize {
        let ts = server.timestamp();
        server.push_gradient(i % 8, &grad, ts).unwrap();
    }
    let start = Instant::now();
    for i in 0..iters {
        let ts = server.timestamp();
        server.push_gradient(i % 8, &grad, ts).unwrap();
    }
    start.elapsed().as_secs_f64() / iters as f64
}

/// Simulated seconds for a capped 1-softsync run on the adversarial
/// 300 MB workload (λ = 32, Rudra-base) with a sharded root.
fn bench_adversarial_sim(shards: usize) -> f64 {
    let mut cfg = SimConfig::paper(
        Protocol::NSoftsync { n: 1 },
        Arch::Base,
        4,
        32,
        1,
        ModelCost::adversarial_300mb(),
    );
    cfg.seed = 5;
    cfg.shards = shards;
    cfg.max_updates = Some(40);
    run_sim(
        &cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.001), Modulation::Auto, 128),
        None,
        None,
    )
    .expect("timing sim")
    .sim_seconds
}

fn main() {
    println!("=== perf_shards — sharded applyUpdate sweep (manual timing) ===\n");
    let n_params = 1_000_000;
    let iters = 300;
    let shard_axis = [1usize, 2, 4, 8];

    // The adversarial sims report *virtual* seconds — host contention
    // cannot perturb them — so they fan out over the parallel point
    // executor (RUDRA_JOBS overrides). The wall-clock push measurements
    // stay strictly serial: running them concurrently would let the
    // points contend for the cores they are trying to time.
    let sims = rudra::harness::sweep::run_indexed(
        rudra::harness::sweep::env_jobs(),
        shard_axis.len(),
        |i| Ok(bench_adversarial_sim(shard_axis[i])),
    )
    .expect("adversarial sims");
    let mut rows = Vec::new();
    for (&shards, &sim) in shard_axis.iter().zip(sims.iter()) {
        let per_push = bench_server_push(n_params, shards, iters);
        rows.push((shards, per_push, sim));
    }

    let base_push = rows[0].1;
    let base_sim = rows[0].2;
    let mut t = Table::new(&[
        "S",
        "push+apply 1M",
        "speedup ×",
        "adversarial sim (s)",
        "sim speedup ×",
    ]);
    for &(shards, per_push, sim) in &rows {
        t.row(vec![
            shards.to_string(),
            rudra::util::fmt_secs(per_push),
            f(base_push / per_push, 2),
            f(sim, 1),
            f(base_sim / sim, 2),
        ]);
    }
    t.print();

    println!(
        "\napplyUpdate wall time should fall as S grows (scoped-thread parallel \
         apply); adversarial sim time falls as the root NIC stops serializing \
         every push (§3.3)."
    );
}
