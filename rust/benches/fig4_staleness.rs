//! Figure 4: average gradient staleness ⟨σ⟩ vs weight-update step for
//! (a) 1-softsync & 2-softsync and (b) λ-softsync at λ = 30, plus the
//! staleness histogram inset and the paper's two §5.1 measurements:
//! ⟨σ⟩ ≈ n and P[σ > 2n] < 1e-4.
//!
//! Reproduced with *real* gradients (synthetic CNN via PJRT) under
//! simulated cluster timing, so the staleness arises from the same
//! compute/communication race the paper measured.

use rudra::config::RunConfig;
use rudra::coordinator::engine_sim::{run_sim, SimConfig};
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::harness::paper;
use rudra::harness::providers::CnnProvider;
use rudra::harness::Workspace;
use rudra::netsim::cluster::ClusterSpec;
use rudra::netsim::cost::LearnerCompute;
use rudra::params::optimizer::Optimizer;
use rudra::stats::table::{f, Table};

fn main() {
    paper::banner("Figure 4 — gradient staleness under n-softsync (λ=30)");
    let ws = Workspace::open_default().expect("run `make artifacts` first");
    let lambda = 30;
    let epochs = if paper::full_grid() { 8 } else { 2 };

    let mut t = Table::new(&[
        "protocol",
        "paper ⟨σ⟩",
        "reproduced ⟨σ⟩",
        "max σ",
        "2n bound",
        "P[σ>2n]",
    ]);
    for n in [1usize, 2, lambda] {
        let cfg = RunConfig {
            protocol: Protocol::NSoftsync { n },
            mu: 128,
            lambda,
            epochs,
            ..RunConfig::default()
        };
        let grad = ws.cnn_grad(cfg.mu).expect("grad exec");
        let mut provider = CnnProvider::new(&grad, &ws.train, cfg.mu, lambda, cfg.seed);
        let sim_cfg = SimConfig {
            protocol: cfg.protocol,
            arch: Arch::Base,
            mu: cfg.mu,
            lambda,
            epochs,
            seed: cfg.seed,
            cluster: ClusterSpec::p775(),
            compute: LearnerCompute::p775(),
            model: ws.cnn_cost(),
            shards: cfg.shards,
            eval_each_epoch: false,
            max_updates: None,
            churn: cfg.churn.clone(),
            rescale: cfg.rescale,
            checkpoint_every_updates: cfg.checkpoint_every,
            hetero: cfg.hetero.clone(),
            adaptive: cfg.adaptive.clone(),
            compress: cfg.compress,
            stop_after_events: None,
            sim_checkpoint_path: None,
            trace: false,
            trace_path: None,
            collect_metrics: false,
            metrics_every: None,
            profile: false,
            faults: cfg.faults.clone(),
        };
        let theta0 = ws.cnn_init().unwrap();
        let optimizer = Optimizer::new(cfg.optimizer, 0.0, theta0.len());
        let r = run_sim(&sim_cfg, theta0, optimizer, cfg.lr_policy(), Some(&mut provider), None)
            .expect("sim");
        let avg = r.staleness.overall_avg();
        let tail = r.staleness.frac_exceeding(2 * n as u64);
        t.row(vec![
            format!("{n}-softsync"),
            format!("≈{n}"),
            f(avg, 2),
            r.staleness.max.to_string(),
            (2 * n).to_string(),
            format!("{tail:.5}"),
        ]);
        // Figure 4(b) inset: histogram for the λ-softsync run.
        if n == lambda {
            println!("\nFig 4(b) inset — staleness distribution for {lambda}-softsync:");
            let total: u64 = r.staleness.histogram.iter().sum();
            for (sigma, &count) in r.staleness.histogram.iter().enumerate() {
                if count == 0 {
                    continue;
                }
                let frac = count as f64 / total as f64;
                let bar = "#".repeat((frac * 120.0).round() as usize);
                println!("  σ={sigma:>3}  {frac:>7.4}  {bar}");
            }
            println!();
        }
        assert!(
            (n as f64 * 0.3..=n as f64 * 2.0).contains(&avg),
            "⟨σ⟩ = {avg} should be ≈ n = {n}"
        );
        assert!(tail < 1e-2, "σ tail beyond 2n too heavy: {tail}");
    }
    t.print();
    println!("\n⟨σ⟩ ≈ n and σ ≲ 2n reproduced ✓");
}
