//! Observability is purely observational: turning tracing and metrics on
//! must leave every SimResult field bit-identical to a quiet run, the
//! same property `hetero none` pins. Also checks the traces themselves
//! are well-formed Chrome trace JSON — including across a mid-flight
//! stop + resume — and that the metrics snapshot agrees with the
//! engine's own counts.

use rudra::coordinator::engine_sim::{run_sim, SimConfig, SimEngine, SimResult};
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::elastic::membership::ChurnSchedule;
use rudra::elastic::rescaler::RescalePolicy;
use rudra::netsim::cluster::ClusterSpec;
use rudra::netsim::cost::{LearnerCompute, ModelCost};
use rudra::obs::trace::{self, TraceEvent};
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::straggler::adaptive::AdaptiveSpec;
use rudra::straggler::hetero::HeteroSpec;
use rudra::util::json::Json;

fn tiny_model(samples_per_epoch: u64) -> ModelCost {
    ModelCost { name: "tiny", flops_per_sample: 1.0e6, bytes: 1.0e3, samples_per_epoch }
}

fn base_cfg(protocol: Protocol, shards: usize) -> SimConfig {
    SimConfig {
        protocol,
        arch: Arch::Base,
        mu: 4,
        lambda: 6,
        epochs: 2,
        seed: 23,
        cluster: ClusterSpec::p775(),
        compute: LearnerCompute::p775(),
        model: tiny_model(240),
        shards,
        eval_each_epoch: false,
        max_updates: None,
        churn: ChurnSchedule::none(),
        rescale: RescalePolicy::None,
        checkpoint_every_updates: 0,
        hetero: HeteroSpec::parse("none").unwrap(),
        adaptive: AdaptiveSpec::none(),
        compress: rudra::comm::codec::CodecSpec::None,
        stop_after_events: None,
        sim_checkpoint_path: None,
        trace: false,
        trace_path: None,
        collect_metrics: false,
        metrics_every: None,
        profile: false,
        faults: rudra::netsim::faults::FaultSpec::none(),
    }
}

fn run_timing(cfg: &SimConfig) -> SimResult {
    run_sim(
        cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
        None,
        None,
    )
    .unwrap()
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Every observable SimResult field must match bit for bit (floats are
/// compared by their IEEE 754 bit patterns, not tolerance). The trace
/// and metrics fields themselves are excluded — they are exactly what
/// differs between an observed and a quiet run.
fn assert_same(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.sim_seconds.to_bits(), b.sim_seconds.to_bits(), "{ctx}: sim_seconds");
    assert_eq!(a.updates, b.updates, "{ctx}: updates");
    assert_eq!(a.events_processed, b.events_processed, "{ctx}: events_processed");
    assert_eq!(a.shard_updates, b.shard_updates, "{ctx}: shard_updates");
    assert_eq!(a.staleness.totals(), b.staleness.totals(), "{ctx}: staleness totals");
    assert_eq!(a.staleness.max, b.staleness.max, "{ctx}: staleness max");
    assert_eq!(a.staleness.histogram, b.staleness.histogram, "{ctx}: staleness histogram");
    assert_eq!(
        bits(&a.staleness.per_update_avg),
        bits(&b.staleness.per_update_avg),
        "{ctx}: staleness series"
    );
    assert_eq!(a.epochs.len(), b.epochs.len(), "{ctx}: epoch count");
    for (ea, eb) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(ea.epoch, eb.epoch, "{ctx}: epoch index");
        assert_eq!(ea.sim_time.to_bits(), eb.sim_time.to_bits(), "{ctx}: epoch time");
        assert_eq!(ea.active_lambda, eb.active_lambda, "{ctx}: epoch λ_active");
    }
    assert_eq!(format!("{:?}", a.churn), format!("{:?}", b.churn), "{ctx}: churn log");
    assert_eq!(bits(&a.recovery_secs), bits(&b.recovery_secs), "{ctx}: recovery");
    assert_eq!(format!("{:?}", a.rescales), format!("{:?}", b.rescales), "{ctx}: rescales");
    assert_eq!(format!("{:?}", a.adaptive), format!("{:?}", b.adaptive), "{ctx}: adaptive");
    assert_eq!(format!("{:?}", a.overlap), format!("{:?}", b.overlap), "{ctx}: overlap");
    assert_eq!(a.final_active_lambda, b.final_active_lambda, "{ctx}: λ_active");
    assert_eq!(a.checkpoints_taken, b.checkpoints_taken, "{ctx}: checkpoints");
    assert_eq!(a.dropped_gradients, b.dropped_gradients, "{ctx}: dropped");
    assert_eq!(a.dropped_by_learner, b.dropped_by_learner, "{ctx}: dropped by learner");
    assert_eq!(
        bits(&a.learner_utilization),
        bits(&b.learner_utilization),
        "{ctx}: utilization"
    );
    assert_eq!(bits(&a.hetero_factors), bits(&b.hetero_factors), "{ctx}: hetero factors");
    assert_eq!(a.root_bytes_in.to_bits(), b.root_bytes_in.to_bits(), "{ctx}: root bytes in");
    assert_eq!(a.root_bytes_out.to_bits(), b.root_bytes_out.to_bits(), "{ctx}: root bytes out");
    assert_eq!(
        bits(&a.comm_bytes_by_learner),
        bits(&b.comm_bytes_by_learner),
        "{ctx}: comm bytes"
    );
}

fn span_names(events: &[TraceEvent]) -> Vec<&'static str> {
    let mut names: Vec<&'static str> = events.iter().map(|e| e.name).collect();
    names.sort_unstable();
    names.dedup();
    names
}

/// The core acceptance property: tracing on, metrics on, and both on
/// reproduce the quiet run bit for bit across the three protocol
/// families and root shards S ∈ {1, 4}. The jittery default cluster is
/// deliberate — identical results prove observation never draws from an
/// engine RNG or reorders events.
#[test]
fn observed_runs_are_bit_identical_to_quiet_runs() {
    for protocol in
        [Protocol::Hardsync, Protocol::NSoftsync { n: 1 }, Protocol::BackupSync { b: 1 }]
    {
        for shards in [1usize, 4] {
            let cfg = base_cfg(protocol, shards);
            let quiet = run_timing(&cfg);
            assert!(quiet.trace.is_none(), "quiet run must not carry a trace");
            assert!(quiet.metrics.is_none(), "quiet run must not carry metrics");

            for (trace_on, metrics_on) in [(true, false), (false, true), (true, true)] {
                let mut obs_cfg = cfg.clone();
                obs_cfg.trace = trace_on;
                obs_cfg.collect_metrics = metrics_on;
                let observed = run_timing(&obs_cfg);
                let ctx =
                    format!("{protocol:?} S={shards} trace={trace_on} metrics={metrics_on}");
                assert_same(&quiet, &observed, &ctx);
                assert_eq!(observed.trace.is_some(), trace_on, "{ctx}: trace presence");
                assert_eq!(observed.metrics.is_some(), metrics_on, "{ctx}: metrics presence");
            }
        }
    }
}

/// A traced hardsync run must produce the full span vocabulary and
/// re-parse as Chrome trace JSON.
#[test]
fn hardsync_trace_covers_the_span_vocabulary() {
    let mut cfg = base_cfg(Protocol::Hardsync, 2);
    cfg.trace = true;
    cfg.checkpoint_every_updates = 5;
    let r = run_timing(&cfg);
    let events = r.trace.expect("trace was on");
    assert!(!events.is_empty());
    let names = span_names(&events);
    for expect in ["apply_update", "barrier_wait", "broadcast", "checkpoint", "compute", "push"]
    {
        assert!(names.contains(&expect), "missing span {expect:?}, got {names:?}");
    }
    // and the rendered JSON is loadable trace-event format
    let text = trace::to_json(&events).to_string();
    let parsed = Json::parse(&text).expect("trace JSON must re-parse");
    let rows = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    // 3 process-name metadata rows lead the event stream
    assert_eq!(rows.len(), events.len() + 3);
    assert!(rows.iter().skip(3).all(|e| {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        ph == "X" || ph == "i"
    }));
}

/// Async protocols exercise the pull path instead of the barrier.
#[test]
fn softsync_trace_has_pull_spans_not_barrier_waits() {
    let mut cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 1);
    cfg.trace = true;
    let r = run_timing(&cfg);
    let names = span_names(&r.trace.expect("trace was on"));
    assert!(names.contains(&"pull"), "got {names:?}");
    assert!(!names.contains(&"barrier_wait"), "got {names:?}");
}

/// `--trace FILE` writes the timeline to disk as well.
#[test]
fn trace_path_writes_a_loadable_file() {
    let dir = std::env::temp_dir().join(format!("rudra_obs_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    let mut cfg = base_cfg(Protocol::Hardsync, 1);
    cfg.trace = true;
    cfg.trace_path = Some(path.clone());
    let r = run_timing(&cfg);
    assert!(r.trace.is_some());
    let text = std::fs::read_to_string(&path).unwrap();
    let parsed = Json::parse(&text).unwrap();
    assert!(!parsed.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

/// Traced stop + resume: both segments yield well-formed traces, the
/// resumed segment picks up at virtual times past the cut, and the
/// resumed trajectory still matches the uninterrupted one bit for bit.
#[test]
fn traced_stop_and_resume_produces_well_formed_segments() {
    let cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 1);
    let full = run_timing(&cfg);
    let k = (full.events_processed / 2).max(1);

    let mut stop_cfg = cfg.clone();
    stop_cfg.trace = true;
    stop_cfg.stop_after_events = Some(k);
    let stopped = run_timing(&stop_cfg);
    assert_eq!(stopped.events_processed, k);
    let first = stopped.trace.expect("stopped segment records a trace");
    assert!(!first.is_empty(), "first segment has spans");
    Json::parse(&trace::to_json(&first).to_string()).expect("first segment re-parses");
    let ckpt = stopped.sim_checkpoint.expect("mid-flight stop captures a checkpoint");

    let mut resume_cfg = cfg.clone();
    resume_cfg.trace = true;
    let mut engine = SimEngine::new(
        &resume_cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
        None,
        None,
    );
    engine.install_sim_checkpoint(&ckpt).unwrap();
    let resumed = engine.run().unwrap();
    assert_same(&full, &resumed, "traced resume");
    let second = resumed.trace.expect("resumed segment records a trace");
    assert!(!second.is_empty(), "second segment has spans");
    Json::parse(&trace::to_json(&second).to_string()).expect("second segment re-parses");
    // the resumed timeline continues past the cut, it does not restart
    let cut_us = stopped.sim_seconds * 1e6;
    assert!(
        second.iter().any(|e| e.ts_us >= cut_us),
        "resumed spans should extend beyond the cut at {cut_us}µs"
    );
}

/// Time-series collection (`--metrics-every`) is as observational as the
/// rest: a sampled run reproduces the quiet trajectory bit for bit across
/// the protocol families and shard counts, and the series itself obeys
/// its schema (windows over monotone virtual time, aligned arrays).
#[test]
fn series_sampled_runs_are_bit_identical_to_quiet_runs() {
    for protocol in
        [Protocol::Hardsync, Protocol::NSoftsync { n: 1 }, Protocol::BackupSync { b: 1 }]
    {
        for shards in [1usize, 4] {
            let cfg = base_cfg(protocol, shards);
            let quiet = run_timing(&cfg);

            let mut series_cfg = cfg.clone();
            series_cfg.metrics_every = Some(0.5);
            let sampled = run_timing(&series_cfg);
            let ctx = format!("{protocol:?} S={shards} series");
            assert_same(&quiet, &sampled, &ctx);

            // metrics_every alone arms a snapshot, and the series rides
            // inside it
            let m = sampled.metrics.expect("metrics_every implies a snapshot");
            let series = m.get("series").unwrap();
            assert_eq!(series.get("every_secs").unwrap().as_f64().unwrap(), 0.5, "{ctx}");
            let t = series.get("t").unwrap().as_f64_vec().unwrap();
            assert!(!t.is_empty(), "{ctx}: final_flush guarantees a sample");
            assert!(t.windows(2).all(|w| w[0] < w[1]), "{ctx}: sample times advance: {t:?}");
            for key in [
                "mean_staleness",
                "max_staleness",
                "queue_depth",
                "active_lambda",
                "bytes_per_sec",
                "barrier_wait_mean",
                "loss_mean",
            ] {
                let col = series.get(key).unwrap().as_arr().unwrap();
                assert_eq!(col.len(), t.len(), "{ctx}: {key} aligns with t");
            }
            assert!(series.get("epoch").is_ok(), "{ctx}: epoch sub-series present");
            assert!(series.get("adaptive_n").is_ok(), "{ctx}: adaptive sub-series present");
        }
    }
}

/// The live engine's wall-clock trace (tentpole 2): spans arrive with the
/// expected vocabulary, non-negative wall offsets, and per-lane monotone
/// start times (learner stamps are causally ordered: compute → send →
/// server receipt → reply → next compute).
#[test]
fn live_trace_spans_are_well_formed_over_wall_time() {
    use rudra::coordinator::engine_live::{run_live, LiveConfig};
    use rudra::coordinator::learner::{GradProvider, MockProvider};

    let dim = 8;
    let cfg = LiveConfig {
        protocol: Protocol::NSoftsync { n: 1 },
        mu: 4,
        lambda: 3,
        epochs: 3,
        samples_per_epoch: 96,
        shards: 1,
        log_every: 0,
        elastic: None,
        compress: rudra::comm::codec::CodecSpec::None,
        checkpoint_every: 0,
        collect_metrics: false,
        trace: true,
        metrics_every: None,
        profile: false,
        faults: rudra::netsim::faults::FaultSpec::none(),
    };
    let providers: Vec<Box<dyn GradProvider + Send>> = (0..cfg.lambda)
        .map(|_| Box::new(MockProvider::new(vec![0.0; dim])) as Box<dyn GradProvider + Send>)
        .collect();
    let r = run_live(
        &cfg,
        FlatVec::from_vec(vec![1.0; dim]),
        Optimizer::new(OptimizerKind::Sgd, 0.0, dim),
        LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128),
        providers,
    )
    .unwrap();
    let events = r.trace.expect("trace was on");
    let names = span_names(&events);
    for expect in ["apply_update", "compute", "push"] {
        assert!(names.contains(&expect), "missing {expect:?}, got {names:?}");
    }
    assert!(
        events.iter().all(|e| e.ts_us >= 0.0 && e.dur_us >= 0.0),
        "wall offsets from the run epoch are non-negative"
    );
    // per-lane causal order: each (pid, tid) lane's start times advance
    let mut lanes: std::collections::BTreeMap<(u64, u64), f64> = std::collections::BTreeMap::new();
    for e in &events {
        let last = lanes.entry((e.pid, e.tid)).or_insert(0.0);
        assert!(
            e.ts_us >= *last,
            "lane ({}, {}) went backwards: {} after {}",
            e.pid,
            e.tid,
            e.ts_us,
            last
        );
        *last = e.ts_us;
    }
    // and the rendered JSON is loadable trace-event format
    Json::parse(&trace::to_json(&events).to_string()).expect("live trace re-parses");
    // untraced runs stay exactly as quiet as before
    let mut quiet_cfg = cfg.clone();
    quiet_cfg.trace = false;
    let providers2: Vec<Box<dyn GradProvider + Send>> = (0..quiet_cfg.lambda)
        .map(|_| Box::new(MockProvider::new(vec![0.0; dim])) as Box<dyn GradProvider + Send>)
        .collect();
    let r2 = run_live(
        &quiet_cfg,
        FlatVec::from_vec(vec![1.0; dim]),
        Optimizer::new(OptimizerKind::Sgd, 0.0, dim),
        LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128),
        providers2,
    )
    .unwrap();
    assert!(r2.trace.is_none());
}

/// Per-point sweep observability (tentpole 3), tested through the same
/// machinery `Sweep::run_point` uses — `run_indexed` workers each running
/// a traced sim with its own per-slug output file. Every grid label gets
/// a file, and the bytes are identical at any `jobs` value.
#[test]
fn sweep_style_per_point_files_exist_for_every_label_and_are_jobs_invariant() {
    use rudra::config::RunConfig;
    use rudra::harness::sweep::{point_slug, run_indexed};

    let dir = std::env::temp_dir().join(format!("rudra_obs_sweep_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    // a small λ grid, like `sweep --lambdas 2,4`
    let lambdas = [2usize, 4];
    let slugs: Vec<String> = lambdas
        .iter()
        .map(|&lambda| {
            let mut rc = RunConfig::default();
            rc.mu = 4;
            rc.lambda = lambda;
            point_slug(&rc)
        })
        .collect();

    let run_grid = |jobs: usize, sub: &str| -> Vec<(String, String)> {
        let out = dir.join(sub);
        let results = run_indexed(jobs, lambdas.len(), |i| {
            let mut cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 1);
            cfg.lambda = lambdas[i];
            cfg.trace = true;
            cfg.trace_path = Some(out.join(format!("{}.trace.json", slugs[i])));
            cfg.metrics_every = Some(0.5);
            let r = run_timing(&cfg);
            let m = r.metrics.expect("metrics_every arms the snapshot");
            rudra::util::write_atomic(
                &out.join(format!("{}.metrics.json", slugs[i])),
                &m.to_string(),
            )?;
            Ok(())
        });
        results.unwrap();
        slugs
            .iter()
            .map(|s| {
                let trace =
                    std::fs::read_to_string(out.join(format!("{s}.trace.json"))).unwrap();
                let metrics =
                    std::fs::read_to_string(out.join(format!("{s}.metrics.json"))).unwrap();
                (trace, metrics)
            })
            .collect()
    };

    let serial = run_grid(1, "serial");
    let parallel = run_grid(2, "parallel");
    for (i, slug) in slugs.iter().enumerate() {
        assert!(
            Json::parse(&serial[i].0).is_ok() && Json::parse(&serial[i].1).is_ok(),
            "{slug}: per-point files re-parse"
        );
        assert_eq!(serial[i], parallel[i], "{slug}: jobs-invariant bytes");
    }
    // no stray .tmp files survive the atomic writes
    for sub in ["serial", "parallel"] {
        for entry in std::fs::read_dir(dir.join(sub)).unwrap() {
            let name = entry.unwrap().file_name().into_string().unwrap();
            assert!(!name.ends_with(".tmp"), "leftover temp file {name}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The metrics snapshot must agree with the engine's own counts: one
/// apply_update per update, λ push lanes, staleness totals, byte flows.
#[test]
fn metrics_snapshot_agrees_with_engine_counts() {
    let mut cfg = base_cfg(Protocol::Hardsync, 2);
    cfg.collect_metrics = true;
    let r = run_timing(&cfg);
    let m = r.metrics.expect("metrics were on");

    let counters = m.get("counters").unwrap();
    assert_eq!(counters.get("apply_update").unwrap().as_u64().unwrap(), r.updates);
    assert!(counters.get("compute_done").unwrap().as_u64().unwrap() > 0);

    let pushes = m.get("pushes_by_learner").unwrap().as_u64_vec().unwrap();
    assert_eq!(pushes.len(), cfg.lambda);
    assert!(pushes.iter().all(|&p| p > 0), "every learner pushed: {pushes:?}");

    let staleness = m.get("staleness").unwrap();
    assert_eq!(staleness.get("count").unwrap().as_u64().unwrap(), r.staleness.totals().0);

    let shard_updates = m.get("shard_updates").unwrap().as_u64_vec().unwrap();
    assert_eq!(shard_updates, r.shard_updates);

    assert_eq!(m.get("root_bytes_in").unwrap().as_f64().unwrap(), r.root_bytes_in);
    assert_eq!(m.get("root_bytes_out").unwrap().as_f64().unwrap(), r.root_bytes_out);

    // hardsync rounds barrier-synchronize: the wait histogram must fill
    let barrier = m.get("barrier").unwrap();
    assert!(barrier.get("rounds").unwrap().as_u64().unwrap() > 0);
    assert!(m.get("queue_depth_high_water").unwrap().as_u64().unwrap() > 0);
}

/// The critical-path profiler (tentpole) is as observational as the rest:
/// a profiled run reproduces the quiet trajectory bit for bit across the
/// protocol families and shard counts, and the profile rides the metrics
/// snapshot without arming anything else.
#[test]
fn profiled_runs_are_bit_identical_to_quiet_runs() {
    for protocol in
        [Protocol::Hardsync, Protocol::NSoftsync { n: 1 }, Protocol::BackupSync { b: 1 }]
    {
        for shards in [1usize, 4] {
            let cfg = base_cfg(protocol, shards);
            let quiet = run_timing(&cfg);

            let mut prof_cfg = cfg.clone();
            prof_cfg.profile = true;
            let profiled = run_timing(&prof_cfg);
            let ctx = format!("{protocol:?} S={shards} profile");
            assert_same(&quiet, &profiled, &ctx);
            assert!(profiled.trace.is_none(), "{ctx}: profiling must not arm the trace");
            let m = profiled.metrics.expect("profile implies a metrics snapshot");
            let p = m.get("profile").unwrap();
            assert_eq!(p.get("mode").unwrap().as_str().unwrap(), "critical_path", "{ctx}");
            assert_eq!(p.get("timebase").unwrap().as_str().unwrap(), "sim", "{ctx}");
        }
    }
}

/// The attribution is an exact partition: the seven category totals sum
/// to `total_secs`, which is the run's own virtual time, and the per-
/// learner blame covers the same span.
#[test]
fn profile_categories_exactly_partition_the_runtime() {
    use rudra::obs::profile::CATEGORY_NAMES;
    for protocol in
        [Protocol::Hardsync, Protocol::NSoftsync { n: 1 }, Protocol::BackupSync { b: 1 }]
    {
        for shards in [1usize, 4] {
            let mut cfg = base_cfg(protocol, shards);
            cfg.profile = true;
            let r = run_timing(&cfg);
            let ctx = format!("{protocol:?} S={shards}");
            let m = r.metrics.expect("profile implies a metrics snapshot");
            let p = m.get("profile").unwrap();

            let total = p.get("total_secs").unwrap().as_f64().unwrap();
            assert_eq!(
                total.to_bits(),
                r.sim_seconds.to_bits(),
                "{ctx}: total_secs is the run's own clock"
            );
            let cats = p.get("categories").unwrap();
            let mut sum = 0.0;
            for name in CATEGORY_NAMES {
                let secs = cats.get(name).unwrap().as_f64().unwrap();
                assert!(secs >= 0.0, "{ctx}: {name} is non-negative, got {secs}");
                sum += secs;
            }
            let tol = 1e-9 * total.max(1.0);
            assert!(
                (sum - total).abs() <= tol,
                "{ctx}: categories must sum to total: {sum} vs {total}"
            );
            assert_eq!(
                p.get("updates").unwrap().as_u64().unwrap(),
                r.updates,
                "{ctx}: one chain per weight update"
            );
        }
    }
}

/// Every what-if projection is a lower bound on a shorter run: within
/// [0, total_secs], and removing a cost never projects longer.
#[test]
fn profile_whatifs_stay_within_bounds() {
    for protocol in
        [Protocol::Hardsync, Protocol::NSoftsync { n: 1 }, Protocol::BackupSync { b: 1 }]
    {
        let mut cfg = base_cfg(protocol, 2);
        cfg.profile = true;
        let r = run_timing(&cfg);
        let m = r.metrics.expect("profile implies a metrics snapshot");
        let p = m.get("profile").unwrap();
        let total = p.get("total_secs").unwrap().as_f64().unwrap();
        let w = p.get("whatif").unwrap();
        for key in
            ["zero_wire_secs", "zero_barrier_secs", "balanced_learners_secs", "fast_root_secs"]
        {
            let secs = w.get(key).unwrap().as_f64().unwrap();
            assert!(
                (0.0..=total).contains(&secs),
                "{protocol:?}: {key}={secs} outside [0, {total}]"
            );
        }
    }
}

/// The acceptance contrast: at λ=30, hardsync's critical path carries at
/// least twice the barrier-wait share of 1-softsync's (softsync has no
/// barrier at all, so its share is exactly zero and hardsync's positive).
#[test]
fn hardsync_attributes_more_barrier_wait_than_softsync() {
    let barrier_share = |protocol: Protocol| -> f64 {
        let mut cfg = base_cfg(protocol, 2);
        cfg.lambda = 30;
        cfg.profile = true;
        let r = run_timing(&cfg);
        let m = r.metrics.expect("profile implies a metrics snapshot");
        let p = m.get("profile").unwrap();
        let total = p.get("total_secs").unwrap().as_f64().unwrap();
        p.get("categories").unwrap().get("barrier_wait").unwrap().as_f64().unwrap() / total
    };
    let hard = barrier_share(Protocol::Hardsync);
    let soft = barrier_share(Protocol::NSoftsync { n: 1 });
    assert_eq!(soft, 0.0, "1-softsync never waits at a barrier");
    assert!(hard > 0.0, "hardsync at λ=30 must blame the barrier");
    assert!(
        hard >= 2.0 * soft,
        "hardsync barrier share {hard} should be ≥ 2× softsync's {soft}"
    );
}

/// The live engine's profile (wall-clock side): aggregate category totals
/// ride the metrics snapshot with the honest `aggregate` mode tag.
#[test]
fn live_profile_rides_the_metrics_snapshot_as_aggregate() {
    use rudra::coordinator::engine_live::{run_live, LiveConfig};
    use rudra::coordinator::learner::{GradProvider, MockProvider};
    use rudra::obs::profile::CATEGORY_NAMES;

    let dim = 8;
    let cfg = LiveConfig {
        protocol: Protocol::Hardsync,
        mu: 4,
        lambda: 3,
        epochs: 2,
        samples_per_epoch: 96,
        shards: 1,
        log_every: 0,
        elastic: None,
        compress: rudra::comm::codec::CodecSpec::None,
        checkpoint_every: 0,
        collect_metrics: false,
        trace: false,
        metrics_every: None,
        profile: true,
        faults: rudra::netsim::faults::FaultSpec::none(),
    };
    let providers: Vec<Box<dyn GradProvider + Send>> = (0..cfg.lambda)
        .map(|_| Box::new(MockProvider::new(vec![0.0; dim])) as Box<dyn GradProvider + Send>)
        .collect();
    let r = run_live(
        &cfg,
        FlatVec::from_vec(vec![1.0; dim]),
        Optimizer::new(OptimizerKind::Sgd, 0.0, dim),
        LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128),
        providers,
    )
    .unwrap();
    assert!(r.trace.is_none(), "profiling must not arm the trace");
    let m = r.metrics.expect("profile implies a metrics snapshot");
    let p = m.get("profile").unwrap();
    assert_eq!(p.get("mode").unwrap().as_str().unwrap(), "aggregate");
    assert_eq!(p.get("timebase").unwrap().as_str().unwrap(), "wall");
    assert!(p.get("whatif").is_err(), "no critical-path claim, no what-ifs");
    let cats = p.get("categories").unwrap();
    let mut sum = 0.0;
    for name in CATEGORY_NAMES {
        let secs = cats.get(name).unwrap().as_f64().unwrap();
        assert!(secs >= 0.0, "{name} is non-negative, got {secs}");
        sum += secs;
    }
    assert!(sum > 0.0, "a real run accumulates some attributed time");
    assert!(p.get("updates").unwrap().as_u64().unwrap() > 0);
}
