//! Observability is purely observational: turning tracing and metrics on
//! must leave every SimResult field bit-identical to a quiet run, the
//! same property `hetero none` pins. Also checks the traces themselves
//! are well-formed Chrome trace JSON — including across a mid-flight
//! stop + resume — and that the metrics snapshot agrees with the
//! engine's own counts.

use rudra::coordinator::engine_sim::{run_sim, SimConfig, SimEngine, SimResult};
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::elastic::membership::ChurnSchedule;
use rudra::elastic::rescaler::RescalePolicy;
use rudra::netsim::cluster::ClusterSpec;
use rudra::netsim::cost::{LearnerCompute, ModelCost};
use rudra::obs::trace::{self, TraceEvent};
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::straggler::adaptive::AdaptiveSpec;
use rudra::straggler::hetero::HeteroSpec;
use rudra::util::json::Json;

fn tiny_model(samples_per_epoch: u64) -> ModelCost {
    ModelCost { name: "tiny", flops_per_sample: 1.0e6, bytes: 1.0e3, samples_per_epoch }
}

fn base_cfg(protocol: Protocol, shards: usize) -> SimConfig {
    SimConfig {
        protocol,
        arch: Arch::Base,
        mu: 4,
        lambda: 6,
        epochs: 2,
        seed: 23,
        cluster: ClusterSpec::p775(),
        compute: LearnerCompute::p775(),
        model: tiny_model(240),
        shards,
        eval_each_epoch: false,
        max_updates: None,
        churn: ChurnSchedule::none(),
        rescale: RescalePolicy::None,
        checkpoint_every_updates: 0,
        hetero: HeteroSpec::parse("none").unwrap(),
        adaptive: AdaptiveSpec::none(),
        compress: rudra::comm::codec::CodecSpec::None,
        stop_after_events: None,
        sim_checkpoint_path: None,
        trace: false,
        trace_path: None,
        collect_metrics: false,
    }
}

fn run_timing(cfg: &SimConfig) -> SimResult {
    run_sim(
        cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
        None,
        None,
    )
    .unwrap()
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Every observable SimResult field must match bit for bit (floats are
/// compared by their IEEE 754 bit patterns, not tolerance). The trace
/// and metrics fields themselves are excluded — they are exactly what
/// differs between an observed and a quiet run.
fn assert_same(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.sim_seconds.to_bits(), b.sim_seconds.to_bits(), "{ctx}: sim_seconds");
    assert_eq!(a.updates, b.updates, "{ctx}: updates");
    assert_eq!(a.events_processed, b.events_processed, "{ctx}: events_processed");
    assert_eq!(a.shard_updates, b.shard_updates, "{ctx}: shard_updates");
    assert_eq!(a.staleness.totals(), b.staleness.totals(), "{ctx}: staleness totals");
    assert_eq!(a.staleness.max, b.staleness.max, "{ctx}: staleness max");
    assert_eq!(a.staleness.histogram, b.staleness.histogram, "{ctx}: staleness histogram");
    assert_eq!(
        bits(&a.staleness.per_update_avg),
        bits(&b.staleness.per_update_avg),
        "{ctx}: staleness series"
    );
    assert_eq!(a.epochs.len(), b.epochs.len(), "{ctx}: epoch count");
    for (ea, eb) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(ea.epoch, eb.epoch, "{ctx}: epoch index");
        assert_eq!(ea.sim_time.to_bits(), eb.sim_time.to_bits(), "{ctx}: epoch time");
        assert_eq!(ea.active_lambda, eb.active_lambda, "{ctx}: epoch λ_active");
    }
    assert_eq!(format!("{:?}", a.churn), format!("{:?}", b.churn), "{ctx}: churn log");
    assert_eq!(bits(&a.recovery_secs), bits(&b.recovery_secs), "{ctx}: recovery");
    assert_eq!(format!("{:?}", a.rescales), format!("{:?}", b.rescales), "{ctx}: rescales");
    assert_eq!(format!("{:?}", a.adaptive), format!("{:?}", b.adaptive), "{ctx}: adaptive");
    assert_eq!(format!("{:?}", a.overlap), format!("{:?}", b.overlap), "{ctx}: overlap");
    assert_eq!(a.final_active_lambda, b.final_active_lambda, "{ctx}: λ_active");
    assert_eq!(a.checkpoints_taken, b.checkpoints_taken, "{ctx}: checkpoints");
    assert_eq!(a.dropped_gradients, b.dropped_gradients, "{ctx}: dropped");
    assert_eq!(a.dropped_by_learner, b.dropped_by_learner, "{ctx}: dropped by learner");
    assert_eq!(
        bits(&a.learner_utilization),
        bits(&b.learner_utilization),
        "{ctx}: utilization"
    );
    assert_eq!(bits(&a.hetero_factors), bits(&b.hetero_factors), "{ctx}: hetero factors");
    assert_eq!(a.root_bytes_in.to_bits(), b.root_bytes_in.to_bits(), "{ctx}: root bytes in");
    assert_eq!(a.root_bytes_out.to_bits(), b.root_bytes_out.to_bits(), "{ctx}: root bytes out");
    assert_eq!(
        bits(&a.comm_bytes_by_learner),
        bits(&b.comm_bytes_by_learner),
        "{ctx}: comm bytes"
    );
}

fn span_names(events: &[TraceEvent]) -> Vec<&'static str> {
    let mut names: Vec<&'static str> = events.iter().map(|e| e.name).collect();
    names.sort_unstable();
    names.dedup();
    names
}

/// The core acceptance property: tracing on, metrics on, and both on
/// reproduce the quiet run bit for bit across the three protocol
/// families and root shards S ∈ {1, 4}. The jittery default cluster is
/// deliberate — identical results prove observation never draws from an
/// engine RNG or reorders events.
#[test]
fn observed_runs_are_bit_identical_to_quiet_runs() {
    for protocol in
        [Protocol::Hardsync, Protocol::NSoftsync { n: 1 }, Protocol::BackupSync { b: 1 }]
    {
        for shards in [1usize, 4] {
            let cfg = base_cfg(protocol, shards);
            let quiet = run_timing(&cfg);
            assert!(quiet.trace.is_none(), "quiet run must not carry a trace");
            assert!(quiet.metrics.is_none(), "quiet run must not carry metrics");

            for (trace_on, metrics_on) in [(true, false), (false, true), (true, true)] {
                let mut obs_cfg = cfg.clone();
                obs_cfg.trace = trace_on;
                obs_cfg.collect_metrics = metrics_on;
                let observed = run_timing(&obs_cfg);
                let ctx =
                    format!("{protocol:?} S={shards} trace={trace_on} metrics={metrics_on}");
                assert_same(&quiet, &observed, &ctx);
                assert_eq!(observed.trace.is_some(), trace_on, "{ctx}: trace presence");
                assert_eq!(observed.metrics.is_some(), metrics_on, "{ctx}: metrics presence");
            }
        }
    }
}

/// A traced hardsync run must produce the full span vocabulary and
/// re-parse as Chrome trace JSON.
#[test]
fn hardsync_trace_covers_the_span_vocabulary() {
    let mut cfg = base_cfg(Protocol::Hardsync, 2);
    cfg.trace = true;
    cfg.checkpoint_every_updates = 5;
    let r = run_timing(&cfg);
    let events = r.trace.expect("trace was on");
    assert!(!events.is_empty());
    let names = span_names(&events);
    for expect in ["apply_update", "barrier_wait", "broadcast", "checkpoint", "compute", "push"]
    {
        assert!(names.contains(&expect), "missing span {expect:?}, got {names:?}");
    }
    // and the rendered JSON is loadable trace-event format
    let text = trace::to_json(&events).to_string();
    let parsed = Json::parse(&text).expect("trace JSON must re-parse");
    let rows = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    // 3 process-name metadata rows lead the event stream
    assert_eq!(rows.len(), events.len() + 3);
    assert!(rows.iter().skip(3).all(|e| {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        ph == "X" || ph == "i"
    }));
}

/// Async protocols exercise the pull path instead of the barrier.
#[test]
fn softsync_trace_has_pull_spans_not_barrier_waits() {
    let mut cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 1);
    cfg.trace = true;
    let r = run_timing(&cfg);
    let names = span_names(&r.trace.expect("trace was on"));
    assert!(names.contains(&"pull"), "got {names:?}");
    assert!(!names.contains(&"barrier_wait"), "got {names:?}");
}

/// `--trace FILE` writes the timeline to disk as well.
#[test]
fn trace_path_writes_a_loadable_file() {
    let dir = std::env::temp_dir().join(format!("rudra_obs_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    let mut cfg = base_cfg(Protocol::Hardsync, 1);
    cfg.trace = true;
    cfg.trace_path = Some(path.clone());
    let r = run_timing(&cfg);
    assert!(r.trace.is_some());
    let text = std::fs::read_to_string(&path).unwrap();
    let parsed = Json::parse(&text).unwrap();
    assert!(!parsed.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

/// Traced stop + resume: both segments yield well-formed traces, the
/// resumed segment picks up at virtual times past the cut, and the
/// resumed trajectory still matches the uninterrupted one bit for bit.
#[test]
fn traced_stop_and_resume_produces_well_formed_segments() {
    let cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 1);
    let full = run_timing(&cfg);
    let k = (full.events_processed / 2).max(1);

    let mut stop_cfg = cfg.clone();
    stop_cfg.trace = true;
    stop_cfg.stop_after_events = Some(k);
    let stopped = run_timing(&stop_cfg);
    assert_eq!(stopped.events_processed, k);
    let first = stopped.trace.expect("stopped segment records a trace");
    assert!(!first.is_empty(), "first segment has spans");
    Json::parse(&trace::to_json(&first).to_string()).expect("first segment re-parses");
    let ckpt = stopped.sim_checkpoint.expect("mid-flight stop captures a checkpoint");

    let mut resume_cfg = cfg.clone();
    resume_cfg.trace = true;
    let mut engine = SimEngine::new(
        &resume_cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
        None,
        None,
    );
    engine.install_sim_checkpoint(&ckpt).unwrap();
    let resumed = engine.run().unwrap();
    assert_same(&full, &resumed, "traced resume");
    let second = resumed.trace.expect("resumed segment records a trace");
    assert!(!second.is_empty(), "second segment has spans");
    Json::parse(&trace::to_json(&second).to_string()).expect("second segment re-parses");
    // the resumed timeline continues past the cut, it does not restart
    let cut_us = stopped.sim_seconds * 1e6;
    assert!(
        second.iter().any(|e| e.ts_us >= cut_us),
        "resumed spans should extend beyond the cut at {cut_us}µs"
    );
}

/// The metrics snapshot must agree with the engine's own counts: one
/// apply_update per update, λ push lanes, staleness totals, byte flows.
#[test]
fn metrics_snapshot_agrees_with_engine_counts() {
    let mut cfg = base_cfg(Protocol::Hardsync, 2);
    cfg.collect_metrics = true;
    let r = run_timing(&cfg);
    let m = r.metrics.expect("metrics were on");

    let counters = m.get("counters").unwrap();
    assert_eq!(counters.get("apply_update").unwrap().as_u64().unwrap(), r.updates);
    assert!(counters.get("compute_done").unwrap().as_u64().unwrap() > 0);

    let pushes = m.get("pushes_by_learner").unwrap().as_u64_vec().unwrap();
    assert_eq!(pushes.len(), cfg.lambda);
    assert!(pushes.iter().all(|&p| p > 0), "every learner pushed: {pushes:?}");

    let staleness = m.get("staleness").unwrap();
    assert_eq!(staleness.get("count").unwrap().as_u64().unwrap(), r.staleness.totals().0);

    let shard_updates = m.get("shard_updates").unwrap().as_u64_vec().unwrap();
    assert_eq!(shard_updates, r.shard_updates);

    assert_eq!(m.get("root_bytes_in").unwrap().as_f64().unwrap(), r.root_bytes_in);
    assert_eq!(m.get("root_bytes_out").unwrap().as_f64().unwrap(), r.root_bytes_out);

    // hardsync rounds barrier-synchronize: the wait histogram must fill
    let barrier = m.get("barrier").unwrap();
    assert!(barrier.get("rounds").unwrap().as_u64().unwrap() > 0);
    assert!(m.get("queue_depth_high_water").unwrap().as_u64().unwrap() > 0);
}
