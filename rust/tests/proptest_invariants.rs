//! Property-based invariant tests over the coordinator's routing,
//! batching, and state machinery, using the seeded case generator in
//! `rudra::util::prop` (the offline vendor set has no proptest; cases are
//! fully determined by (seed, index) so failures replay exactly).

use rudra::coordinator::clock::StalenessStats;
use rudra::coordinator::protocol::{Accumulator, Protocol};
use rudra::coordinator::server::{ParameterServer, ServerConfig};
use rudra::coordinator::shard::ShardedServer;
use rudra::elastic::checkpoint::Checkpoint;
use rudra::coordinator::tree::PsTree;
use rudra::netsim::cluster::Endpoint;
use rudra::netsim::event::EventQueue;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::util::prop::check;
use rudra::util::rng::Rng;

/// For any (λ, n): c = ⌊λ/n⌋ clamped to [1, λ] — updates always make
/// progress and never demand more gradients than learners exist.
#[test]
fn prop_gradients_per_update_in_bounds() {
    check(
        "gradients_per_update_bounds",
        1,
        500,
        |r| {
            let lambda = r.below(64) as usize + 1;
            let n = r.below(96) as usize + 1;
            (lambda, n)
        },
        |&(lambda, n)| {
            let c = Protocol::NSoftsync { n }.gradients_per_update(lambda);
            if c == 0 {
                return Err("c = 0 stalls the server".into());
            }
            if c > lambda {
                return Err(format!("c = {c} > λ = {lambda}"));
            }
            if n <= lambda && c != lambda / n {
                return Err(format!("c = {c} != ⌊{lambda}/{n}⌋"));
            }
            Ok(())
        },
    );
}

/// The accumulator's average equals the arithmetic mean of the pushed
/// gradients regardless of push order and count.
#[test]
fn prop_accumulator_average_exact() {
    check(
        "accumulator_average",
        2,
        200,
        |r| {
            let c = r.below(12) as usize + 1;
            let dim = r.below(8) as usize + 1;
            let grads: Vec<Vec<f32>> = (0..c)
                .map(|_| (0..dim).map(|_| (r.f64() * 8.0 - 4.0) as f32).collect())
                .collect();
            grads
        },
        |grads| {
            let dim = grads[0].len();
            let lambda = grads.len();
            let mut acc = Accumulator::new(Protocol::NSoftsync { n: 1 }, lambda, dim);
            for (i, g) in grads.iter().enumerate() {
                acc.push(i, &FlatVec::from_vec(g.clone()), 0).map_err(|e| e.to_string())?;
            }
            if !acc.ready() {
                return Err("not ready after λ pushes".into());
            }
            let (avg, clock) = acc.take_update();
            if clock.len() != lambda {
                return Err("vector clock wrong length".into());
            }
            for d in 0..dim {
                let want: f32 =
                    grads.iter().map(|g| g[d]).sum::<f32>() / lambda as f32;
                if (avg.data[d] - want).abs() > 1e-4 {
                    return Err(format!("dim {d}: {} != {want}", avg.data[d]));
                }
            }
            Ok(())
        },
    );
}

/// Eq. (2) invariants: ⟨σ⟩ ≥ 0 for causal clocks, and ⟨σ⟩ = 0 iff every
/// gradient was computed at ts = i−1.
#[test]
fn prop_staleness_nonnegative_and_zero_iff_fresh() {
    check(
        "staleness_eq2",
        3,
        400,
        |r| {
            let new_ts = r.below(50) + 1;
            let k = r.below(8) as usize + 1;
            let clocks: Vec<u64> = (0..k).map(|_| r.below(new_ts)).collect();
            (new_ts, clocks)
        },
        |(new_ts, clocks)| {
            let mut s = StalenessStats::default();
            let rec = s.record(*new_ts, clocks);
            if rec.avg_staleness < -1e-9 {
                return Err(format!("negative ⟨σ⟩ {}", rec.avg_staleness));
            }
            let all_fresh = clocks.iter().all(|&t| t == new_ts - 1);
            if all_fresh != (rec.avg_staleness.abs() < 1e-9) {
                return Err(format!(
                    "⟨σ⟩ = {} but all_fresh = {all_fresh}",
                    rec.avg_staleness
                ));
            }
            // histogram total equals clock count
            let total: u64 = s.histogram.iter().sum();
            if total != clocks.len() as u64 {
                return Err("histogram lost gradients".into());
            }
            Ok(())
        },
    );
}

/// Server state machine: for any protocol and any interleaving of
/// learner pushes, timestamps increase exactly on updates, epoch samples
/// accounting is exact, and the weights stay finite.
#[test]
fn prop_server_state_machine() {
    check(
        "server_state",
        4,
        120,
        |r| {
            let lambda = r.below(8) as usize + 1;
            let proto = match r.below(4) {
                0 => Protocol::Hardsync,
                1 => Protocol::NSoftsync { n: r.below(lambda as u64) as usize + 1 },
                2 => Protocol::BackupSync { b: r.below(lambda as u64) as usize },
                _ => Protocol::Async,
            };
            let pushes = r.below(60) as usize + lambda;
            (lambda, proto, pushes, r.next_u64())
        },
        |&(lambda, proto, pushes, seed)| {
            let dim = 3;
            let cfg = ServerConfig {
                protocol: proto,
                mu: 4,
                lambda,
                samples_per_epoch: 32,
                target_epochs: usize::MAX, // never auto-done in this test
                shards: 1,
            };
            let mut server = ParameterServer::new(
                cfg,
                FlatVec::zeros(dim),
                Optimizer::new(OptimizerKind::Sgd, 0.0, dim),
                LrPolicy::new(Schedule::constant(0.01), Modulation::Auto, 128),
            );
            let backup = matches!(proto, Protocol::BackupSync { .. });
            let mut rng = Rng::new(seed);
            let mut ts_seen = 0u64;
            let mut folded = 0u64;
            // hardsync requires round-robin (one push per learner per
            // round); others are arbitrary
            let mut order: Vec<usize> = (0..lambda).collect();
            // backup-sync learners all compute from the round-start
            // weights (the broadcast), so the post-update pushes of a
            // round are genuinely stale and get dropped
            let mut round_ts = 0u64;
            for p in 0..pushes {
                let learner = if proto.is_barrier() {
                    if p % lambda == 0 {
                        rng.shuffle(&mut order);
                        round_ts = server.timestamp();
                    }
                    order[p % lambda]
                } else {
                    rng.usize_below(lambda)
                };
                let g = FlatVec::from_vec(vec![0.1, -0.1, 0.05]);
                let grad_ts = if backup { round_ts } else { server.timestamp() };
                let out = server
                    .push_gradient(learner, &g, grad_ts)
                    .map_err(|e| e.to_string())?;
                if !out.dropped {
                    folded += 1;
                }
                if out.dropped && !backup {
                    return Err("only backup-sync may drop gradients".into());
                }
                if out.updated {
                    if server.timestamp() != ts_seen + 1 {
                        return Err("timestamp must advance by exactly 1".into());
                    }
                    ts_seen = server.timestamp();
                } else if server.timestamp() != ts_seen {
                    return Err("timestamp changed without an update".into());
                }
                if !server.weights().0.is_finite() {
                    return Err("weights went non-finite".into());
                }
            }
            let expected_samples = server.updates
                * proto.gradients_per_update(lambda) as u64
                * 4;
            if server.samples_applied() != expected_samples {
                return Err(format!(
                    "samples {} != updates×c×μ {}",
                    server.samples_applied(),
                    expected_samples
                ));
            }
            // drop accounting is exact: every push either folded or was
            // booked as dropped, and only stale backup pushes drop
            if folded + server.dropped != pushes as u64 {
                return Err(format!(
                    "drop accounting lost pushes: {folded} folded + {} dropped != {pushes}",
                    server.dropped
                ));
            }
            if server.dropped_by().iter().sum::<u64>() != server.dropped {
                return Err("per-learner drop attribution does not add up".into());
            }
            Ok(())
        },
    );
}

/// Sharded server ≡ flat server: for any shard count S, any of the four
/// protocols (including backup-sync's drop rule), any optimizer, and any
/// valid push sequence, the
/// [`ShardedServer`] produces the same update/epoch outcomes, the same
/// timestamps, and weights equal within 1e-6 of the unsharded
/// [`ParameterServer`] — and its per-shard update counters stay in
/// lockstep with the aggregate count.
#[test]
fn prop_sharded_server_matches_unsharded() {
    check(
        "sharded_server_equivalence",
        11,
        80,
        |r| {
            let lambda = r.below(6) as usize + 1;
            let proto = match r.below(4) {
                0 => Protocol::Hardsync,
                1 => Protocol::NSoftsync { n: r.below(lambda as u64 + 2) as usize + 1 },
                2 => Protocol::BackupSync { b: r.below(lambda as u64) as usize },
                _ => Protocol::Async,
            };
            let shards = r.below(8) as usize + 1;
            let dim = r.below(24) as usize + 1;
            let opt = r.below(3);
            let modulation = r.below(3);
            let pushes = r.below(40) as usize + lambda;
            (lambda, proto, shards, dim, opt, modulation, pushes, r.next_u64())
        },
        |&(lambda, proto, shards, dim, opt, modulation, pushes, seed)| {
            let kind = match opt {
                0 => OptimizerKind::Sgd,
                1 => OptimizerKind::Momentum { momentum: 0.9 },
                _ => OptimizerKind::Adagrad { eps: 1e-8 },
            };
            let modulation = match modulation {
                0 => Modulation::None,
                1 => Modulation::StalenessReciprocal,
                _ => Modulation::PerGradient,
            };
            let mk_cfg = |s| ServerConfig {
                protocol: proto,
                mu: 4,
                lambda,
                samples_per_epoch: 64,
                target_epochs: usize::MAX,
                shards: s,
            };
            let theta0 = FlatVec::from_vec((0..dim).map(|i| (i % 7) as f32 * 0.25 - 0.75).collect());
            let lr = LrPolicy::new(Schedule::constant(0.05), modulation, 128);
            let mut flat = ParameterServer::new(
                mk_cfg(1),
                theta0.clone(),
                Optimizer::new(kind, 1e-4, dim),
                lr.clone(),
            );
            let mut sharded = ShardedServer::new(
                mk_cfg(shards),
                theta0,
                Optimizer::new(kind, 1e-4, dim),
                lr,
            );
            let backup = matches!(proto, Protocol::BackupSync { .. });
            let mut rng = Rng::new(seed);
            let mut order: Vec<usize> = (0..lambda).collect();
            let mut round_ts = 0u64;
            for p in 0..pushes {
                let learner = if proto.is_barrier() {
                    if p % lambda == 0 {
                        rng.shuffle(&mut order);
                        round_ts = flat.timestamp();
                    }
                    order[p % lambda]
                } else {
                    rng.usize_below(lambda)
                };
                let g = FlatVec::from_vec(
                    (0..dim).map(|_| (rng.f64() * 0.4 - 0.2) as f32).collect(),
                );
                // fresh or slightly stale pull (never ahead of the clock);
                // backup-sync learners all compute from the round-start
                // broadcast, so post-update pushes of a round are stale
                // and must drop identically on both servers
                let ts = if backup {
                    round_ts
                } else {
                    flat.timestamp().saturating_sub(rng.below(3))
                };
                let a = flat.push_gradient(learner, &g, ts).map_err(|e| e.to_string())?;
                let b = sharded.push_gradient(learner, &g, ts).map_err(|e| e.to_string())?;
                if a.updated != b.updated
                    || a.epoch_completed != b.epoch_completed
                    || a.dropped != b.dropped
                {
                    return Err(format!("outcome diverged at push {p}"));
                }
                if flat.timestamp() != sharded.timestamp() {
                    return Err("timestamps diverged".into());
                }
            }
            if flat.dropped != sharded.dropped
                || flat.dropped_by() != sharded.dropped_by()
            {
                return Err(format!(
                    "drop counters diverged: flat {} {:?} vs sharded {} {:?}",
                    flat.dropped,
                    flat.dropped_by(),
                    sharded.dropped,
                    sharded.dropped_by()
                ));
            }
            let want = flat.weights().0;
            let got = sharded.assemble_weights();
            for d in 0..dim {
                if (want.data[d] - got.data[d]).abs() > 1e-6 {
                    return Err(format!(
                        "dim {d}: sharded {} vs flat {} (S = {shards})",
                        got.data[d], want.data[d]
                    ));
                }
            }
            if sharded.shard_updates() != vec![sharded.updates; shards] {
                return Err(format!(
                    "shard counters out of lockstep: {:?} vs {}",
                    sharded.shard_updates(),
                    sharded.updates
                ));
            }
            Ok(())
        },
    );
}

/// Checkpoint → restore → resume reproduces the *bit-identical*
/// fixed-seed trajectory of an uninterrupted run, for all four protocols
/// (backup-sync's drop counters included) and S ∈ {1, 4} shards, with the
/// checkpoint taken at an arbitrary point
/// — including mid-accumulation and mid-hardsync-round (the pending sums
/// and vector clock ride along in the checkpoint).
#[test]
fn prop_checkpoint_restore_resumes_bit_identical() {
    check(
        "checkpoint_resume_equivalence",
        13,
        72,
        |r| {
            let lambda = r.below(5) as usize + 2;
            let proto = match r.below(4) {
                0 => Protocol::Hardsync,
                1 => Protocol::NSoftsync { n: r.below(lambda as u64) as usize + 1 },
                2 => Protocol::BackupSync { b: r.below(lambda as u64) as usize },
                _ => Protocol::Async,
            };
            let shards = if r.below(2) == 0 { 1 } else { 4 };
            let dim = r.below(20) as usize + 1;
            let opt = r.below(3);
            let pushes = r.below(50) as usize + lambda;
            let split = r.below(pushes as u64) as usize;
            (lambda, proto, shards, dim, opt, pushes, split, r.next_u64())
        },
        |&(lambda, proto, shards, dim, opt, pushes, split, seed)| {
            let kind = match opt {
                0 => OptimizerKind::Sgd,
                1 => OptimizerKind::Momentum { momentum: 0.9 },
                _ => OptimizerKind::Adagrad { eps: 1e-8 },
            };
            let mk = || {
                ShardedServer::new(
                    ServerConfig {
                        protocol: proto,
                        mu: 4,
                        lambda,
                        samples_per_epoch: 48,
                        target_epochs: usize::MAX,
                        shards,
                    },
                    FlatVec::from_vec(
                        (0..dim).map(|i| (i % 5) as f32 * 0.3 - 0.6).collect(),
                    ),
                    Optimizer::new(kind, 1e-4, dim),
                    LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128),
                )
            };
            // Pre-generate the push sequence so both runs see the same one.
            let mut rng = Rng::new(seed);
            let mut order: Vec<usize> = (0..lambda).collect();
            let seq: Vec<(usize, Vec<f32>)> = (0..pushes)
                .map(|p| {
                    let learner = if proto.is_barrier() {
                        if p % lambda == 0 {
                            rng.shuffle(&mut order);
                        }
                        order[p % lambda]
                    } else {
                        rng.usize_below(lambda)
                    };
                    let g: Vec<f32> =
                        (0..dim).map(|_| (rng.f64() * 0.4 - 0.2) as f32).collect();
                    (learner, g)
                })
                .collect();
            let push = |s: &mut ShardedServer, (learner, g): &(usize, Vec<f32>), ts: u64| {
                s.push_gradient(*learner, &FlatVec::from_vec(g.clone()), ts)
                    .map(|_| ())
                    .map_err(|e| e.to_string())
            };
            // Run A: uninterrupted. Gradient timestamps are recorded so
            // run B replays the exact same inputs across the restore
            // boundary: fresh pulls for the non-barrier protocols, the
            // round-start broadcast for backup-sync (whose post-update
            // pushes of a round are stale and get dropped — exercising the
            // drop counters through the checkpoint format).
            let backup = matches!(proto, Protocol::BackupSync { .. });
            let mut a = mk();
            let mut ts_used = Vec::with_capacity(pushes);
            let mut round_ts = 0u64;
            for (p, item) in seq.iter().enumerate() {
                if p % lambda == 0 {
                    round_ts = a.timestamp();
                }
                let ts = if backup { round_ts } else { a.timestamp() };
                ts_used.push(ts);
                push(&mut a, item, ts)?;
            }
            // Run B: interrupted at `split`, checkpointed through the
            // JSON text form, restored, resumed.
            let mut b = mk();
            for (item, &ts) in seq[..split].iter().zip(&ts_used) {
                push(&mut b, item, ts)?;
            }
            let text = Checkpoint::capture("prop", &b, &[]).to_json_string();
            let mut b = Checkpoint::from_json_str(&text)
                .map_err(|e| e.to_string())?
                .restore()
                .map_err(|e| format!("restore failed (S = {shards}): {e:#}"))?
                .server;
            for (item, &ts) in seq[split..].iter().zip(&ts_used[split..]) {
                push(&mut b, item, ts)?;
            }
            if a.assemble_weights().data != b.assemble_weights().data {
                return Err(format!(
                    "trajectory diverged after restore at split {split}/{pushes} \
                     (S = {shards}, {proto:?}, {kind:?})"
                ));
            }
            if a.timestamp() != b.timestamp()
                || a.samples_applied() != b.samples_applied()
                || a.updates != b.updates
                || a.shard_updates() != b.shard_updates()
            {
                return Err("clock/epoch bookkeeping diverged after restore".into());
            }
            if a.staleness.count != b.staleness.count
                || a.staleness.max != b.staleness.max
            {
                return Err("staleness history diverged after restore".into());
            }
            if a.dropped != b.dropped || a.dropped_by() != b.dropped_by() {
                return Err(format!(
                    "backup-sync drop counters diverged after restore: \
                     {} {:?} vs {} {:?}",
                    a.dropped,
                    a.dropped_by(),
                    b.dropped,
                    b.dropped_by()
                ));
            }
            Ok(())
        },
    );
}

/// Tree routing: every learner maps to exactly one leaf, leaves partition
/// the learners, and fan-in bounds hold.
#[test]
fn prop_tree_partitions_learners() {
    check(
        "tree_partition",
        5,
        300,
        |r| {
            let lambda = r.below(200) as usize + 1;
            let fanout = r.below(16) as usize + 1;
            (lambda, fanout)
        },
        |&(lambda, fanout)| {
            let t = PsTree::new(lambda, fanout);
            let mut seen = vec![false; lambda];
            for leaf in 0..t.n_leaves {
                let mut count = 0;
                for l in t.members(leaf) {
                    if seen[l] {
                        return Err(format!("learner {l} in two leaves"));
                    }
                    seen[l] = true;
                    if t.leaf_of[l] != leaf {
                        return Err("leaf_of disagrees with members".into());
                    }
                    count += 1;
                }
                if count == 0 {
                    return Err(format!("empty leaf {leaf}"));
                }
                if count > fanout {
                    return Err(format!("leaf {leaf} over fan-in: {count}"));
                }
            }
            if !seen.iter().all(|&s| s) {
                return Err("some learner unrouted".into());
            }
            Ok(())
        },
    );
}

/// Event queue: any schedule pops in nondecreasing time order with FIFO
/// tie-breaking, and never loses events.
#[test]
fn prop_event_queue_ordering() {
    check(
        "event_queue",
        6,
        200,
        |r| {
            let n = r.below(200) as usize + 1;
            let times: Vec<f64> = (0..n).map(|_| (r.below(50) as f64) * 0.125).collect();
            times
        },
        |times| {
            let mut q = EventQueue::new();
            for (i, &t) in times.iter().enumerate() {
                q.schedule_at(t, i);
            }
            let mut popped = Vec::new();
            let mut last_t = f64::NEG_INFINITY;
            let mut last_seq_at_t: i64 = -1;
            while let Some((t, i)) = q.pop() {
                if t < last_t {
                    return Err("time went backwards".into());
                }
                if t > last_t {
                    last_seq_at_t = -1;
                    last_t = t;
                }
                // FIFO among equal times means insertion index increases
                if (times[i] - t).abs() > 1e-12 {
                    return Err("event popped at wrong time".into());
                }
                if (i as i64) < last_seq_at_t {
                    return Err("FIFO tie-break violated".into());
                }
                last_seq_at_t = i as i64;
                popped.push(i);
            }
            if popped.len() != times.len() {
                return Err("lost events".into());
            }
            Ok(())
        },
    );
}

/// Endpoint contention: reservations never overlap and total busy time
/// equals the sum of durations.
#[test]
fn prop_endpoint_serializes() {
    check(
        "endpoint_serialization",
        7,
        200,
        |r| {
            let n = r.below(40) as usize + 1;
            (0..n)
                .map(|_| (r.f64() * 10.0, 0.01 + r.f64()))
                .collect::<Vec<(f64, f64)>>()
        },
        |reqs| {
            let mut e = Endpoint::default();
            let mut windows: Vec<(f64, f64)> = Vec::new();
            let mut total = 0.0;
            for &(earliest, dur) in reqs {
                let done = e.reserve(earliest, dur);
                let start = done - dur;
                if start + 1e-12 < earliest {
                    return Err("transfer started before requested".into());
                }
                for &(s, d) in &windows {
                    if start + 1e-9 < d && s + 1e-9 < done {
                        return Err(format!(
                            "overlap: [{start},{done}] vs [{s},{d}]"
                        ));
                    }
                }
                windows.push((start, done));
                total += dur;
            }
            if (e.busy_total - total).abs() > 1e-6 {
                return Err("busy_total wrong".into());
            }
            Ok(())
        },
    );
}
