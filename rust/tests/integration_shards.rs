//! Sharded-engine integration: protocol semantics and the §5.1 staleness
//! claims must survive sharding the root tier (S > 1 root endpoints,
//! parallel applyUpdate), end to end through the virtual-time engine with
//! the mock quadratic provider.

use rudra::coordinator::engine_sim::{run_sim, SimConfig, SimResult};
use rudra::coordinator::learner::MockProvider;
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::netsim::cost::ModelCost;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;

fn tiny_model() -> ModelCost {
    ModelCost { name: "tiny", flops_per_sample: 1.0e6, bytes: 1.0e3, samples_per_epoch: 256 }
}

fn run_sharded(
    protocol: Protocol,
    arch: Arch,
    lambda: usize,
    shards: usize,
    epochs: usize,
    numeric: bool,
    seed: u64,
) -> SimResult {
    let dim = 8;
    let mut cfg = SimConfig::paper(protocol, arch, 4, lambda, epochs, tiny_model());
    cfg.seed = seed;
    cfg.shards = shards;
    let theta0 = FlatVec::from_vec((0..dim).map(|i| (i as f32 % 5.0) - 2.0).collect());
    let opt = Optimizer::new(OptimizerKind::Sgd, 0.0, dim);
    let lr = LrPolicy::new(Schedule::constant(0.02), Modulation::StalenessReciprocal, 128);
    let mut provider = MockProvider::new(vec![0.0; dim]);
    run_sim(
        &cfg,
        theta0,
        opt,
        lr,
        if numeric { Some(&mut provider) } else { None },
        None,
    )
    .unwrap()
}

/// §5.1 under the sharded engine: for n-softsync with n ∈ {1, 4, λ} and
/// λ ∈ {4, 8}, ⟨σ⟩ tracks n and the σ ≤ 2n bound holds — exactly as the
/// paper states it: in expectation and with a vanishing tail
/// (P[σ > 2n] < 1e-4 at paper scale; these short runs allow a small
/// jitter slack beyond the hard 2n line).
#[test]
fn sigma_le_2n_bound_survives_sharding() {
    for &lambda in &[4usize, 8] {
        let mut ns = vec![1usize, 4, lambda];
        ns.dedup();
        for n in ns {
            let r = run_sharded(
                Protocol::NSoftsync { n },
                Arch::Base,
                lambda,
                4,
                4,
                true,
                17,
            );
            let avg = r.staleness.overall_avg();
            assert!(
                (0.0..=2.4 * n as f64).contains(&avg),
                "λ={lambda} {n}-softsync: ⟨σ⟩ = {avg}, expected ≈ {n} (and never > 2.4n)"
            );
            let tail = r.staleness.frac_exceeding(2 * n as u64);
            assert!(
                tail <= 0.05,
                "λ={lambda} {n}-softsync: P[σ > 2n] = {tail} too heavy"
            );
            assert!(
                r.staleness.max <= 2 * n as u64 + 3,
                "λ={lambda} {n}-softsync: max σ = {} grossly violates σ ≤ 2n",
                r.staleness.max
            );
        }
    }
}

/// Hardsync over a sharded root stays stale-free: shards advance in
/// lockstep with the barrier, so σ ≡ 0 at any S.
#[test]
fn hardsync_sharded_stays_stale_free() {
    for shards in [1usize, 2, 4] {
        let r = run_sharded(Protocol::Hardsync, Arch::Base, 4, shards, 3, true, 7);
        assert_eq!(r.staleness.max, 0, "S={shards}");
        assert!(r.updates > 0, "S={shards}");
        let theta = r.theta.unwrap();
        assert!(theta.is_finite() && theta.norm() < 4.0, "S={shards}: |θ| = {}", theta.norm());
    }
}

/// The update budget is shard-invariant: epoch accounting is sample
/// driven, so the same (protocol, λ, epochs) point applies the same
/// number of updates at any S, and every shard's counter matches.
#[test]
fn update_budget_is_shard_invariant() {
    let flat = run_sharded(Protocol::NSoftsync { n: 1 }, Arch::Base, 8, 1, 2, true, 3);
    for shards in [2usize, 4, 8] {
        let r = run_sharded(Protocol::NSoftsync { n: 1 }, Arch::Base, 8, shards, 2, true, 3);
        assert_eq!(r.updates, flat.updates, "S={shards}");
        assert_eq!(r.shard_updates, vec![r.updates; shards], "S={shards}");
        assert_eq!(r.epochs.len(), flat.epochs.len(), "S={shards}");
    }
    assert_eq!(flat.shard_updates, vec![flat.updates]);
}

/// Fixed seed + fixed S replays bit-identically (the engine's
/// determinism guarantee extends to the sharded fabric and server).
#[test]
fn sharded_engine_is_deterministic() {
    let a = run_sharded(Protocol::NSoftsync { n: 2 }, Arch::Base, 4, 4, 2, true, 21);
    let b = run_sharded(Protocol::NSoftsync { n: 2 }, Arch::Base, 4, 4, 2, true, 21);
    assert_eq!(a.sim_seconds, b.sim_seconds);
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.theta.unwrap().data, b.theta.unwrap().data);
    assert_eq!(a.shard_updates, b.shard_updates);
}

/// Sharding composes with every architecture in timing-only mode, and
/// per-shard counters stay truthful without numeric work.
#[test]
fn timing_only_sharded_runs_all_archs() {
    for arch in [Arch::Base, Arch::Adv, Arch::AdvStar] {
        let r = run_sharded(Protocol::NSoftsync { n: 1 }, arch, 8, 4, 2, false, 9);
        assert!(r.sim_seconds > 0.0, "{arch:?}");
        assert!(r.updates > 0, "{arch:?}");
        assert!(r.theta.is_none());
        assert_eq!(r.shard_updates, vec![r.updates; 4], "{arch:?}");
    }
}

/// Sharding the root relieves the §3.3 bottleneck on the adversarial
/// workload: simulated time with S = 4 must beat the flat server on the
/// same (protocol, μ, λ) point at paper scale.
#[test]
fn sharding_reduces_adversarial_root_stall() {
    let time = |shards: usize| {
        let mut cfg = SimConfig::paper(
            Protocol::NSoftsync { n: 1 },
            Arch::Base,
            4,
            32,
            1,
            ModelCost::adversarial_300mb(),
        );
        cfg.seed = 5;
        cfg.shards = shards;
        cfg.max_updates = Some(40);
        run_sim(
            &cfg,
            FlatVec::zeros(0),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
            LrPolicy::new(Schedule::constant(0.001), Modulation::Auto, 128),
            None,
            None,
        )
        .unwrap()
        .sim_seconds
    };
    let flat = time(1);
    let sharded = time(4);
    assert!(
        sharded < flat,
        "4 root shards should beat the flat root on 300 MB pushes: {sharded} vs {flat}"
    );
}
