//! Sharded-engine integration: protocol semantics and the §5.1 staleness
//! claims must survive sharding the root tier (S > 1 root endpoints,
//! parallel applyUpdate), end to end through the virtual-time engine with
//! the mock quadratic provider.

use rudra::coordinator::engine_sim::{run_sim, SimConfig, SimResult};
use rudra::coordinator::learner::MockProvider;
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::server::ServerConfig;
use rudra::coordinator::shard::ShardedServer;
use rudra::coordinator::tree::Arch;
use rudra::netsim::cost::ModelCost;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;

fn tiny_model() -> ModelCost {
    ModelCost { name: "tiny", flops_per_sample: 1.0e6, bytes: 1.0e3, samples_per_epoch: 256 }
}

fn run_sharded(
    protocol: Protocol,
    arch: Arch,
    lambda: usize,
    shards: usize,
    epochs: usize,
    numeric: bool,
    seed: u64,
) -> SimResult {
    let dim = 8;
    let mut cfg = SimConfig::paper(protocol, arch, 4, lambda, epochs, tiny_model());
    cfg.seed = seed;
    cfg.shards = shards;
    let theta0 = FlatVec::from_vec((0..dim).map(|i| (i as f32 % 5.0) - 2.0).collect());
    let opt = Optimizer::new(OptimizerKind::Sgd, 0.0, dim);
    let lr = LrPolicy::new(Schedule::constant(0.02), Modulation::StalenessReciprocal, 128);
    let mut provider = MockProvider::new(vec![0.0; dim]);
    run_sim(
        &cfg,
        theta0,
        opt,
        lr,
        if numeric { Some(&mut provider) } else { None },
        None,
    )
    .unwrap()
}

/// §5.1 under the sharded engine: for n-softsync with n ∈ {1, 4, λ} and
/// λ ∈ {4, 8}, ⟨σ⟩ tracks n and the σ ≤ 2n bound holds — exactly as the
/// paper states it: in expectation and with a vanishing tail
/// (P[σ > 2n] < 1e-4 at paper scale; these short runs allow a small
/// jitter slack beyond the hard 2n line).
#[test]
fn sigma_le_2n_bound_survives_sharding() {
    for &lambda in &[4usize, 8] {
        let mut ns = vec![1usize, 4, lambda];
        ns.dedup();
        for n in ns {
            let r = run_sharded(
                Protocol::NSoftsync { n },
                Arch::Base,
                lambda,
                4,
                4,
                true,
                17,
            );
            let avg = r.staleness.overall_avg();
            assert!(
                (0.0..=2.4 * n as f64).contains(&avg),
                "λ={lambda} {n}-softsync: ⟨σ⟩ = {avg}, expected ≈ {n} (and never > 2.4n)"
            );
            let tail = r.staleness.frac_exceeding(2 * n as u64);
            assert!(
                tail <= 0.05,
                "λ={lambda} {n}-softsync: P[σ > 2n] = {tail} too heavy"
            );
            assert!(
                r.staleness.max <= 2 * n as u64 + 3,
                "λ={lambda} {n}-softsync: max σ = {} grossly violates σ ≤ 2n",
                r.staleness.max
            );
        }
    }
}

/// Hardsync over a sharded root stays stale-free: shards advance in
/// lockstep with the barrier, so σ ≡ 0 at any S.
#[test]
fn hardsync_sharded_stays_stale_free() {
    for shards in [1usize, 2, 4] {
        let r = run_sharded(Protocol::Hardsync, Arch::Base, 4, shards, 3, true, 7);
        assert_eq!(r.staleness.max, 0, "S={shards}");
        assert!(r.updates > 0, "S={shards}");
        let theta = r.theta.unwrap();
        assert!(theta.is_finite() && theta.norm() < 4.0, "S={shards}: |θ| = {}", theta.norm());
    }
}

/// The update budget is shard-invariant: epoch accounting is sample
/// driven, so the same (protocol, λ, epochs) point applies the same
/// number of updates at any S, and every shard's counter matches.
#[test]
fn update_budget_is_shard_invariant() {
    let flat = run_sharded(Protocol::NSoftsync { n: 1 }, Arch::Base, 8, 1, 2, true, 3);
    for shards in [2usize, 4, 8] {
        let r = run_sharded(Protocol::NSoftsync { n: 1 }, Arch::Base, 8, shards, 2, true, 3);
        assert_eq!(r.updates, flat.updates, "S={shards}");
        assert_eq!(r.shard_updates, vec![r.updates; shards], "S={shards}");
        assert_eq!(r.epochs.len(), flat.epochs.len(), "S={shards}");
    }
    assert_eq!(flat.shard_updates, vec![flat.updates]);
}

/// Fixed seed + fixed S replays bit-identically (the engine's
/// determinism guarantee extends to the sharded fabric and server).
#[test]
fn sharded_engine_is_deterministic() {
    let a = run_sharded(Protocol::NSoftsync { n: 2 }, Arch::Base, 4, 4, 2, true, 21);
    let b = run_sharded(Protocol::NSoftsync { n: 2 }, Arch::Base, 4, 4, 2, true, 21);
    assert_eq!(a.sim_seconds, b.sim_seconds);
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.theta.unwrap().data, b.theta.unwrap().data);
    assert_eq!(a.shard_updates, b.shard_updates);
}

/// Sharding composes with every architecture in timing-only mode, and
/// per-shard counters stay truthful without numeric work.
#[test]
fn timing_only_sharded_runs_all_archs() {
    for arch in [Arch::Base, Arch::Adv, Arch::AdvStar] {
        let r = run_sharded(Protocol::NSoftsync { n: 1 }, arch, 8, 4, 2, false, 9);
        assert!(r.sim_seconds > 0.0, "{arch:?}");
        assert!(r.updates > 0, "{arch:?}");
        assert!(r.theta.is_none());
        assert_eq!(r.shard_updates, vec![r.updates; 4], "{arch:?}");
    }
}

/// Property: `backup:0` is hardsync — for any shard count S, any λ, and
/// any hardsync-legal push sequence, a BackupSync{b: 0} server produces
/// the same outcomes and weights (within 1e-6) as a Hardsync server fed
/// identically. (With b = 0 a round closes only once *every* learner has
/// pushed, so no gradient can ever arrive stale and the drop rule is
/// unreachable.)
#[test]
fn prop_backup_zero_equals_hardsync_any_shards() {
    rudra::util::prop::check(
        "backup0_is_hardsync",
        2024,
        60,
        |rng| {
            let lambda = 2 + rng.usize_below(5); // 2..=6
            let shards = 1 + rng.usize_below(6); // 1..=6
            let dim = 1 + rng.usize_below(12); // 1..=12
            let rounds = 1 + rng.usize_below(6);
            // per-round, per-learner, per-dim gradient values
            let grads: Vec<f32> = (0..rounds * lambda * dim)
                .map(|_| (rng.f64() * 2.0 - 1.0) as f32)
                .collect();
            (lambda, shards, dim, rounds, grads)
        },
        |&(lambda, shards, dim, rounds, ref grads)| {
            let mk = |protocol| {
                ShardedServer::new(
                    ServerConfig {
                        protocol,
                        mu: 4,
                        lambda,
                        samples_per_epoch: 1_000_000,
                        target_epochs: 100,
                        shards,
                    },
                    FlatVec::from_vec((0..dim).map(|i| i as f32 * 0.1 - 0.3).collect()),
                    Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, dim),
                    LrPolicy::new(Schedule::constant(0.5), Modulation::None, 128),
                )
            };
            let mut hard = mk(Protocol::Hardsync);
            let mut backup = mk(Protocol::BackupSync { b: 0 });
            for round in 0..rounds {
                for l in 0..lambda {
                    let ts = hard.timestamp();
                    let g = FlatVec::from_vec(
                        grads[(round * lambda + l) * dim..(round * lambda + l + 1) * dim]
                            .to_vec(),
                    );
                    let a = hard.push_gradient(l, &g, ts).map_err(|e| e.to_string())?;
                    let b = backup.push_gradient(l, &g, ts).map_err(|e| e.to_string())?;
                    if a.updated != b.updated
                        || a.avg_staleness != b.avg_staleness
                        || b.dropped
                    {
                        return Err(format!(
                            "outcome diverged at round {round}, learner {l}: \
                             {a:?} vs {b:?}"
                        ));
                    }
                }
            }
            let wa = hard.assemble_weights();
            let wb = backup.assemble_weights();
            for d in 0..dim {
                if (wa.data[d] - wb.data[d]).abs() > 1e-6 {
                    return Err(format!(
                        "θ[{d}] diverged: {} vs {}",
                        wa.data[d], wb.data[d]
                    ));
                }
            }
            if backup.dropped != 0 {
                return Err("backup:0 dropped a gradient".to_string());
            }
            Ok(())
        },
    );
}

/// Backup-sync composes with the sharded engine end to end: rounds close
/// on λ − b folds, shard clocks stay in lockstep, drops are booked, and
/// σ ≡ 0 at any S.
#[test]
fn backup_sync_survives_sharding() {
    for shards in [1usize, 2, 4] {
        let r = run_sharded(Protocol::BackupSync { b: 2 }, Arch::Base, 8, shards, 3, true, 13);
        assert_eq!(r.staleness.max, 0, "S={shards}");
        assert!(r.updates > 0, "S={shards}");
        assert_eq!(r.shard_updates, vec![r.updates; shards], "S={shards}: lockstep");
        assert_eq!(
            r.dropped_by_learner.iter().sum::<u64>(),
            r.dropped_gradients,
            "S={shards}"
        );
        assert!(r.theta.unwrap().is_finite(), "S={shards}");
    }
}

/// Sharding the root relieves the §3.3 bottleneck on the adversarial
/// workload: simulated time with S = 4 must beat the flat server on the
/// same (protocol, μ, λ) point at paper scale.
#[test]
fn sharding_reduces_adversarial_root_stall() {
    let time = |shards: usize| {
        let mut cfg = SimConfig::paper(
            Protocol::NSoftsync { n: 1 },
            Arch::Base,
            4,
            32,
            1,
            ModelCost::adversarial_300mb(),
        );
        cfg.seed = 5;
        cfg.shards = shards;
        cfg.max_updates = Some(40);
        run_sim(
            &cfg,
            FlatVec::zeros(0),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
            LrPolicy::new(Schedule::constant(0.001), Modulation::Auto, 128),
            None,
            None,
        )
        .unwrap()
        .sim_seconds
    };
    let flat = time(1);
    let sharded = time(4);
    assert!(
        sharded < flat,
        "4 root shards should beat the flat root on 300 MB pushes: {sharded} vs {flat}"
    );
}
