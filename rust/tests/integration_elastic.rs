//! Integration tests for the elastic membership subsystem: churn-driven
//! runs through the virtual-time engine (deterministic, zero-jitter
//! cluster), the μ·λ = const rescaler, membership-aware hardsync quorums,
//! and checkpoint/restore round trips at S > 1.

use rudra::coordinator::engine_sim::{run_sim, SimConfig, SimResult};
use rudra::coordinator::learner::MockProvider;
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::server::ServerConfig;
use rudra::coordinator::shard::ShardedServer;
use rudra::coordinator::tree::Arch;
use rudra::elastic::checkpoint::Checkpoint;
use rudra::elastic::membership::{ChurnKind, ChurnSchedule};
use rudra::elastic::rescaler::RescalePolicy;
use rudra::netsim::cluster::ClusterSpec;
use rudra::netsim::cost::{LearnerCompute, ModelCost};
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::straggler::adaptive::AdaptiveSpec;
use rudra::straggler::hetero::HeteroSpec;

const DIM: usize = 4;

fn tiny_model() -> ModelCost {
    ModelCost { name: "tiny", flops_per_sample: 1.0e6, bytes: 1.0e3, samples_per_epoch: 64 }
}

/// Zero-jitter P775: one mini-batch ≈ 1.2 ms (μ=4) of virtual time, so
/// churn events placed at a few milliseconds land mid-run, and every
/// trajectory is exactly reproducible.
fn quiet_cluster() -> ClusterSpec {
    ClusterSpec { compute_jitter: 0.0, straggler_prob: 0.0, ..ClusterSpec::p775() }
}

fn elastic_cfg(
    protocol: Protocol,
    mu: usize,
    lambda: usize,
    epochs: usize,
    churn: &str,
    rescale: RescalePolicy,
) -> SimConfig {
    SimConfig {
        protocol,
        arch: Arch::Base,
        mu,
        lambda,
        epochs,
        seed: 11,
        cluster: quiet_cluster(),
        compute: LearnerCompute::p775(),
        model: tiny_model(),
        shards: 1,
        eval_each_epoch: false,
        max_updates: None,
        churn: ChurnSchedule::parse(churn).unwrap(),
        rescale,
        checkpoint_every_updates: 0,
        hetero: HeteroSpec::none(),
        adaptive: AdaptiveSpec::none(),
        compress: rudra::comm::codec::CodecSpec::None,
        stop_after_events: None,
        sim_checkpoint_path: None,
        trace: false,
        trace_path: None,
        collect_metrics: false,
        metrics_every: None,
        profile: false,
        faults: rudra::netsim::faults::FaultSpec::none(),
    }
}

fn run(cfg: &SimConfig) -> anyhow::Result<SimResult> {
    let mut provider = MockProvider::new(vec![0.0; DIM]);
    run_sim(
        cfg,
        FlatVec::from_vec(vec![1.0, -2.0, 0.5, 3.0]),
        Optimizer::new(OptimizerKind::Sgd, 0.0, DIM),
        LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
        Some(&mut provider),
        None,
    )
}

/// Acceptance (a): under a kill schedule, n-softsync staleness stays
/// within the paper's σ ≤ 2n bound measured against the *shrunk* active
/// set (the quota c = ⌊λ_active/n⌋ is recomputed per kill).
#[test]
fn softsync_staleness_bounded_under_kills() {
    let n = 4;
    let cfg = elastic_cfg(
        Protocol::NSoftsync { n },
        4,
        12,
        8,
        "kill:2@0.003,kill:5@0.004,kill:8@0.005,kill:11@0.006",
        RescalePolicy::None,
    );
    let r = run(&cfg).unwrap();
    assert_eq!(r.final_active_lambda, 8, "4 of 12 learners died");
    assert_eq!(
        r.churn.iter().filter(|c| c.kind == ChurnKind::Kill).count(),
        4,
        "{:?}",
        r.churn
    );
    assert!(r.epochs.len() == 8, "run completed all epochs: {}", r.epochs.len());
    let bound = 2 * n as u64;
    assert!(
        r.staleness.max <= bound,
        "σ_max = {} exceeds 2n = {bound} (λ_active-aware quota)",
        r.staleness.max
    );
    assert_eq!(r.staleness.frac_exceeding(bound), 0.0);
    // the epoch log carries the active-λ column: it must end at 8
    assert_eq!(r.epochs.last().unwrap().active_lambda, 8);
}

/// §5.1 under heterogeneous speeds: with mild persistent skew (a 1.4×
/// and a 1.2× straggler on a zero-jitter cluster) *and* mid-run kills,
/// n-softsync staleness still respects the σ ≤ 2n bound against the
/// shrunk active set — the quota recomputation keeps the bound as λ_active
/// falls, and mild heterogeneity stretches ⟨σ⟩ without breaching 2n.
/// (Heavy skew is a different regime: a 10× straggler's gradients go far
/// beyond 2n, which is exactly what `backup:<b>` exists to cut off.)
#[test]
fn softsync_sigma_bound_survives_mild_heterogeneity_and_kills() {
    let n = 3;
    let mut cfg = elastic_cfg(
        Protocol::NSoftsync { n },
        4,
        12,
        8,
        "kill:5@0.004,kill:8@0.005",
        RescalePolicy::None,
    );
    cfg.hetero = HeteroSpec::parse("slow:0x1.4,slow:3x1.2").unwrap();
    let r = run(&cfg).unwrap();
    assert_eq!(r.final_active_lambda, 10, "2 of 12 learners died");
    assert_eq!(r.epochs.len(), 8, "completed under hetero + kills");
    assert_eq!(r.hetero_factors[0], 1.4);
    assert_eq!(r.hetero_factors[3], 1.2);
    let bound = 2 * n as u64;
    assert!(
        r.staleness.max <= bound,
        "σ_max = {} exceeds 2n = {bound} under mild heterogeneity",
        r.staleness.max
    );
    assert_eq!(r.staleness.frac_exceeding(bound), 0.0);
    // the slow learners actually ran slower: lower utilization-normalized
    // throughput shows up as fewer dropped... here simply as determinism
    let again = run(&cfg).unwrap();
    assert_eq!(r.sim_seconds, again.sim_seconds, "hetero elastic runs replay exactly");
}

/// Acceptance (b): hardsync completes — no deadlock — when a learner dies
/// mid-round; the membership-aware quorum closes the barrier with the
/// survivors.
#[test]
fn hardsync_completes_after_death() {
    let cfg = elastic_cfg(Protocol::Hardsync, 4, 4, 3, "kill:2@0.005", RescalePolicy::None);
    let r = run(&cfg).unwrap();
    assert_eq!(
        r.epochs.len(),
        3,
        "hardsync must reach its target epochs after the death (updates = {})",
        r.updates
    );
    assert_eq!(r.final_active_lambda, 3);
    assert!(r.churn.iter().any(|c| c.kind == ChurnKind::Kill && c.learner == 2));
    assert!(r.theta.unwrap().is_finite());
}

/// Hardsync also survives a kill + later rejoin (warm restart): the
/// rejoined learner re-enters the barrier under its old id.
#[test]
fn hardsync_kill_then_rejoin_restores_quorum() {
    let cfg = elastic_cfg(
        Protocol::Hardsync,
        4,
        4,
        4,
        "kill:1@0.004,rejoin:1@0.009",
        RescalePolicy::None,
    );
    let r = run(&cfg).unwrap();
    assert_eq!(r.epochs.len(), 4, "completed after kill+rejoin");
    assert_eq!(r.final_active_lambda, 4, "rejoin restored the full quorum");
    assert_eq!(r.recovery_secs.len(), 1);
    let rec = r.recovery_secs[0];
    assert!((rec - 0.005).abs() < 1e-9, "recovery time = rejoin − kill, got {rec}");
    let kinds: Vec<ChurnKind> =
        r.churn.iter().filter(|c| c.learner == 1).map(|c| c.kind).collect();
    assert_eq!(kinds, vec![ChurnKind::Kill, ChurnKind::Rejoin]);
}

/// Acceptance (c): with the rescaler on, μ·λ_active stays within ±1
/// mini-batch of the configured product μ₀·λ₀ across every churn event.
#[test]
fn rescaler_holds_mu_lambda_product_across_churn() {
    let product = 64; // μ₀ = 8, λ₀ = 8
    let cfg = elastic_cfg(
        Protocol::NSoftsync { n: 1 },
        8,
        8,
        8,
        "kill:1@0.004,kill:5@0.006,rejoin:1@0.010",
        RescalePolicy::MuLambdaConst,
    );
    let r = run(&cfg).unwrap();
    // initial normalization + 2 kills + 1 rejoin
    assert_eq!(r.rescales.len(), 4, "{:?}", r.rescales);
    for rec in &r.rescales {
        let err = (rec.mu * rec.active_lambda).abs_diff(product);
        assert!(
            err <= rec.mu,
            "at t={}: μ={} λ={} drifts {err} > 1 mini-batch from P={product}",
            rec.at,
            rec.mu,
            rec.active_lambda
        );
        assert!(rec.quota >= 1);
    }
    // μ actually moved: 8 → (λ=7) 9 → (λ=6) 11 → (λ=7) 9
    let mus: Vec<usize> = r.rescales.iter().map(|rec| rec.mu).collect();
    assert_eq!(mus, vec![8, 9, 11, 9]);
    assert_eq!(r.final_active_lambda, 7);
    // one rescaled update can apply > samples_per_epoch samples (6·11 =
    // 66 > 64) and cross two boundaries in one record, so check the
    // final epoch number, not the record count
    assert!(r.epochs.last().unwrap().epoch >= 8, "rescaled run completed");
}

/// Acceptance (d): checkpoint → restore round trip is bit-identical with
/// shards > 1, including mid-round accumulator state, and the restored
/// server continues the exact trajectory.
#[test]
fn checkpoint_restore_bit_identical_with_shards() {
    let dim = 13;
    let cfg = ServerConfig {
        protocol: Protocol::NSoftsync { n: 2 },
        mu: 4,
        lambda: 6,
        samples_per_epoch: 96,
        target_epochs: 10,
        shards: 4,
    };
    let mut orig = ShardedServer::new(
        cfg,
        FlatVec::from_vec((0..dim).map(|i| (i as f32).sin()).collect()),
        Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 1e-4, dim),
        LrPolicy::new(Schedule::constant(0.1), Modulation::Auto, 128),
    );
    let grad = |i: usize| {
        FlatVec::from_vec((0..dim).map(|d| (((i * 7 + d) % 11) as f32 - 5.0) * 0.07).collect())
    };
    for i in 0..11 {
        let ts = orig.timestamp();
        orig.push_gradient(i % 6, &grad(i), ts).unwrap();
    }
    // capture mid-round (11 pushes, quota 3 ⇒ 2 pending), round-trip
    // through the JSON text form as the CI restore path would
    let text = Checkpoint::capture("integration", &orig, &[]).to_json_string();
    let mut restored = Checkpoint::from_json_str(&text).unwrap().restore().unwrap().server;
    assert_eq!(restored.n_shards(), 4);
    assert_eq!(restored.assemble_weights().data, orig.assemble_weights().data);
    assert_eq!(restored.timestamp(), orig.timestamp());
    assert_eq!(restored.shard_updates(), orig.shard_updates());
    for i in 11..30 {
        let ts = orig.timestamp();
        let a = orig.push_gradient(i % 6, &grad(i), ts).unwrap();
        let b = restored.push_gradient(i % 6, &grad(i), ts).unwrap();
        assert_eq!(a.updated, b.updated, "push {i}");
        assert_eq!(a.avg_staleness, b.avg_staleness, "push {i}");
        assert_eq!(a.epoch_completed, b.epoch_completed, "push {i}");
    }
    assert_eq!(
        restored.assemble_weights().data,
        orig.assemble_weights().data,
        "trajectories must stay bit-identical after restore"
    );
    assert_eq!(restored.samples_applied(), orig.samples_applied());
    assert_eq!(restored.staleness.count, orig.staleness.count);
}

/// The engine captures checkpoints on its update interval and the last
/// one restores to a server consistent with the interval.
#[test]
fn engine_checkpoints_on_interval() {
    let mut cfg =
        elastic_cfg(Protocol::NSoftsync { n: 1 }, 4, 4, 3, "none", RescalePolicy::None);
    cfg.shards = 2;
    cfg.checkpoint_every_updates = 3;
    let r = run(&cfg).unwrap();
    assert!(r.checkpoints_taken > 0, "interval checkpoints captured");
    let ckpt = r.last_checkpoint.expect("last checkpoint kept");
    assert_eq!(ckpt.updates().unwrap() % 3, 0);
    let restored = ckpt.restore().unwrap();
    assert_eq!(restored.server.n_shards(), 2);
    assert!(restored.server.assemble_weights().is_finite());
    assert!(restored.rngs.contains_key("engine"), "engine RNG stream checkpointed");
}

/// The checked quota: killing learners below n-softsync's floor is a hard
/// error (c = ⌊λ/n⌋ would be 0), not a silent protocol change.
#[test]
fn softsync_below_n_is_rejected() {
    let cfg =
        elastic_cfg(Protocol::NSoftsync { n: 4 }, 4, 4, 3, "kill:0@0.003", RescalePolicy::None);
    let err = run(&cfg).unwrap_err();
    assert!(err.to_string().contains("softsync"), "{err}");
}

/// Deferred joins: a learner scheduled with `join:` starts outside the
/// quorum and enters it mid-run (spot-instance arrival).
#[test]
fn deferred_join_grows_the_quorum() {
    let cfg = elastic_cfg(
        Protocol::NSoftsync { n: 1 },
        4,
        4,
        4,
        "join:3@0.004",
        RescalePolicy::MuLambdaConst,
    );
    let r = run(&cfg).unwrap();
    assert_eq!(r.final_active_lambda, 4);
    assert!(r.churn.iter().any(|c| c.kind == ChurnKind::Join && c.learner == 3));
    assert_eq!(r.epochs.len(), 4);
    // λ_active grew 3 → 4, so the rescaler tightened μ: P = 16 ⇒ 5 then 4
    let mus: Vec<usize> = r.rescales.iter().map(|rec| rec.mu).collect();
    assert_eq!(mus, vec![5, 4], "{:?}", r.rescales);
}

/// Random churn (rate + downtime) replays bit-identically for a fixed
/// seed — the failure injector draws from its own deterministic stream.
#[test]
fn random_churn_is_deterministic() {
    // mean interarrival 1 ms, mean downtime 4 ms — many kill/rejoin
    // cycles inside a ~20 ms run (the first arrival is virtually certain
    // to land in-run at this rate)
    let cfg = elastic_cfg(
        Protocol::NSoftsync { n: 1 },
        4,
        8,
        8,
        "rate:1000000,downtime:0.004",
        RescalePolicy::MuLambdaConst,
    );
    let a = run(&cfg).unwrap();
    let b = run(&cfg).unwrap();
    assert_eq!(a.sim_seconds, b.sim_seconds);
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.theta.unwrap().data, b.theta.unwrap().data);
    assert_eq!(a.churn.len(), b.churn.len());
    assert!(!a.churn.is_empty(), "the random process actually fired");
    assert!(a.epochs.len() == 8, "completed under random churn");
}

/// CI churn smoke (fast): tiny λ, 2 epochs, forced kill + rejoin with the
/// rescaler on — the whole elastic path end to end in milliseconds of
/// virtual time.
#[test]
fn churn_smoke() {
    let cfg = elastic_cfg(
        Protocol::NSoftsync { n: 1 },
        4,
        4,
        2,
        "kill:1@0.002,rejoin:1@0.005",
        RescalePolicy::MuLambdaConst,
    );
    let r = run(&cfg).unwrap();
    assert_eq!(r.epochs.len(), 2, "completed");
    assert_eq!(r.final_active_lambda, 4);
    assert_eq!(r.recovery_secs.len(), 1);
    assert!(r.churn.len() >= 2, "{:?}", r.churn);
    assert!(r.theta.unwrap().is_finite());
    assert!(!r.rescales.is_empty());
}
