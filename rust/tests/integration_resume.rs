//! Mid-flight sim resume: stop-at-event-k + resume must be bit-identical
//! to an uninterrupted run. Timing-only (the numeric path checkpoints at
//! update boundaries via `checkpoint_every_updates`), on a zero-jitter
//! cluster so every trajectory is exactly reproducible.
//!
//! The matrix covers the three protocol families (hardsync, n-softsync,
//! backup-sync) × root shards S ∈ {1, 4}, plus a loaded point with
//! churn + heterogeneity + adaptive-n + rescaling all live at the cut.

use rudra::coordinator::engine_sim::{run_sim, SimConfig, SimEngine, SimResult};
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::elastic::checkpoint::SimCheckpoint;
use rudra::elastic::membership::ChurnSchedule;
use rudra::elastic::rescaler::RescalePolicy;
use rudra::netsim::cluster::ClusterSpec;
use rudra::netsim::cost::{LearnerCompute, ModelCost};
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::straggler::adaptive::AdaptiveSpec;
use rudra::straggler::hetero::HeteroSpec;

fn tiny_model(samples_per_epoch: u64) -> ModelCost {
    ModelCost { name: "tiny", flops_per_sample: 1.0e6, bytes: 1.0e3, samples_per_epoch }
}

fn quiet_cluster() -> ClusterSpec {
    ClusterSpec { compute_jitter: 0.0, straggler_prob: 0.0, ..ClusterSpec::p775() }
}

fn base_cfg(protocol: Protocol, shards: usize) -> SimConfig {
    SimConfig {
        protocol,
        arch: Arch::Base,
        mu: 4,
        lambda: 6,
        epochs: 2,
        seed: 17,
        cluster: quiet_cluster(),
        compute: LearnerCompute::p775(),
        model: tiny_model(240),
        shards,
        eval_each_epoch: false,
        max_updates: None,
        churn: ChurnSchedule::none(),
        rescale: RescalePolicy::None,
        checkpoint_every_updates: 0,
        hetero: HeteroSpec::parse("none").unwrap(),
        adaptive: AdaptiveSpec::none(),
        compress: rudra::comm::codec::CodecSpec::None,
        stop_after_events: None,
        sim_checkpoint_path: None,
        trace: false,
        trace_path: None,
        collect_metrics: false,
        metrics_every: None,
        profile: false,
        faults: rudra::netsim::faults::FaultSpec::none(),
    }
}

fn new_engine(cfg: &SimConfig) -> SimEngine<'_> {
    SimEngine::new(
        cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
        None,
        None,
    )
}

fn run_timing(cfg: &SimConfig) -> SimResult {
    run_sim(
        cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
        None,
        None,
    )
    .unwrap()
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Every observable SimResult field must match bit for bit (floats are
/// compared by their IEEE 754 bit patterns, not tolerance).
fn assert_same(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.sim_seconds.to_bits(), b.sim_seconds.to_bits(), "{ctx}: sim_seconds");
    assert_eq!(a.updates, b.updates, "{ctx}: updates");
    assert_eq!(a.events_processed, b.events_processed, "{ctx}: events_processed");
    assert_eq!(a.shard_updates, b.shard_updates, "{ctx}: shard_updates");
    assert_eq!(a.staleness.totals(), b.staleness.totals(), "{ctx}: staleness totals");
    assert_eq!(a.staleness.max, b.staleness.max, "{ctx}: staleness max");
    assert_eq!(a.staleness.histogram, b.staleness.histogram, "{ctx}: staleness histogram");
    assert_eq!(
        bits(&a.staleness.per_update_avg),
        bits(&b.staleness.per_update_avg),
        "{ctx}: staleness series"
    );
    assert_eq!(a.epochs.len(), b.epochs.len(), "{ctx}: epoch count");
    for (ea, eb) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(ea.epoch, eb.epoch, "{ctx}: epoch index");
        assert_eq!(ea.sim_time.to_bits(), eb.sim_time.to_bits(), "{ctx}: epoch time");
        assert_eq!(ea.active_lambda, eb.active_lambda, "{ctx}: epoch λ_active");
    }
    assert_eq!(format!("{:?}", a.churn), format!("{:?}", b.churn), "{ctx}: churn log");
    assert_eq!(bits(&a.recovery_secs), bits(&b.recovery_secs), "{ctx}: recovery");
    assert_eq!(format!("{:?}", a.rescales), format!("{:?}", b.rescales), "{ctx}: rescales");
    assert_eq!(format!("{:?}", a.adaptive), format!("{:?}", b.adaptive), "{ctx}: adaptive");
    assert_eq!(format!("{:?}", a.overlap), format!("{:?}", b.overlap), "{ctx}: overlap");
    assert_eq!(a.final_active_lambda, b.final_active_lambda, "{ctx}: λ_active");
    assert_eq!(a.checkpoints_taken, b.checkpoints_taken, "{ctx}: checkpoints");
    assert_eq!(a.dropped_gradients, b.dropped_gradients, "{ctx}: dropped");
    assert_eq!(a.dropped_by_learner, b.dropped_by_learner, "{ctx}: dropped by learner");
    assert_eq!(
        bits(&a.learner_utilization),
        bits(&b.learner_utilization),
        "{ctx}: utilization"
    );
    assert_eq!(bits(&a.hetero_factors), bits(&b.hetero_factors), "{ctx}: hetero factors");
    assert_eq!(a.root_bytes_in.to_bits(), b.root_bytes_in.to_bits(), "{ctx}: root bytes in");
    assert_eq!(a.root_bytes_out.to_bits(), b.root_bytes_out.to_bits(), "{ctx}: root bytes out");
    assert_eq!(
        bits(&a.comm_bytes_by_learner),
        bits(&b.comm_bytes_by_learner),
        "{ctx}: comm bytes"
    );
}

/// Stop the run after `k` processed events, capture the in-memory sim
/// checkpoint, install it into a fresh engine under the original config,
/// and run to completion.
fn stop_and_resume(cfg: &SimConfig, k: u64, ctx: &str) -> SimResult {
    let mut stop_cfg = cfg.clone();
    stop_cfg.stop_after_events = Some(k);
    let stopped = run_timing(&stop_cfg);
    assert_eq!(stopped.events_processed, k, "{ctx}: stop lands exactly at k");
    let ckpt = stopped.sim_checkpoint.expect("mid-flight stop must capture a checkpoint");
    assert_eq!(ckpt.events_processed().unwrap(), k, "{ctx}: checkpoint event count");
    let mut engine = new_engine(cfg);
    engine.install_sim_checkpoint(&ckpt).unwrap();
    engine.run().unwrap()
}

/// The core acceptance property: stop-at-event-k + resume reproduces the
/// uninterrupted run bit for bit across the three protocol families and
/// root shards S ∈ {1, 4}.
#[test]
fn resume_is_bit_identical_across_protocols_and_shards() {
    for protocol in
        [Protocol::Hardsync, Protocol::NSoftsync { n: 1 }, Protocol::BackupSync { b: 1 }]
    {
        for shards in [1usize, 4] {
            let cfg = base_cfg(protocol, shards);
            let full = run_timing(&cfg);
            assert_eq!(full.epochs.len(), 2, "baseline completes");
            // cut early (mid cold-start traffic) and late (steady state)
            for k in [full.events_processed / 4, (3 * full.events_processed) / 4] {
                let ctx = format!("{protocol:?} S={shards} k={k}");
                let resumed = stop_and_resume(&cfg, k.max(1), &ctx);
                assert_same(&full, &resumed, &ctx);
            }
        }
    }
}

/// The loaded point: a scheduled kill, sampled + transient heterogeneity,
/// the adaptive-n controller, and μ·λ rescaling all in force when the
/// run is cut. Everything that carries engine state across the cut —
/// membership phases, hetero RNG + degraded flags, controller state,
/// rescale history — must survive the round trip.
#[test]
fn resume_under_churn_hetero_and_adaptive_is_bit_identical() {
    let mut cfg = base_cfg(Protocol::NSoftsync { n: 2 }, 1);
    cfg.epochs = 3;
    cfg.churn = ChurnSchedule::parse("kill:3@0.005").unwrap();
    cfg.rescale = RescalePolicy::MuLambdaConst;
    cfg.hetero = HeteroSpec::parse("lognormal:0.3,markov:0.1:0.4:4").unwrap();
    cfg.adaptive = AdaptiveSpec::parse("sigma:2").unwrap();
    let full = run_timing(&cfg);
    assert_eq!(full.epochs.len(), 3, "baseline completes");
    assert_eq!(full.churn.len(), 1, "the kill landed");
    for k in [full.events_processed / 5, (4 * full.events_processed) / 5] {
        let ctx = format!("churn+hetero+adaptive k={k}");
        let resumed = stop_and_resume(&cfg, k.max(1), &ctx);
        assert_same(&full, &resumed, &ctx);
    }
}

/// The checkpoint must survive the disk round trip (save → load →
/// install), not just the in-memory hand-off: this is the `--resume FILE`
/// CLI path.
#[test]
fn resume_from_disk_matches_uninterrupted_run() {
    let cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 4);
    let full = run_timing(&cfg);
    let k = full.events_processed / 2;

    let dir = std::env::temp_dir().join(format!("rudra_resume_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sim.ckpt.json");

    let mut stop_cfg = cfg.clone();
    stop_cfg.stop_after_events = Some(k);
    stop_cfg.sim_checkpoint_path = Some(path.clone());
    let stopped = run_timing(&stop_cfg);
    assert!(stopped.sim_checkpoint.is_some());
    let ckpt = SimCheckpoint::load(&path).unwrap();
    assert_eq!(ckpt.events_processed().unwrap(), k);

    let mut engine = new_engine(&cfg);
    engine.install_sim_checkpoint(&ckpt).unwrap();
    let resumed = engine.run().unwrap();
    assert_same(&full, &resumed, "disk roundtrip");
    std::fs::remove_dir_all(&dir).ok();
}

/// A checkpoint captured under one config must refuse to install under
/// another: resuming λ = 6 state into a λ = 8 engine would silently
/// corrupt the trajectory, so the fingerprint check has to catch it.
#[test]
fn resume_rejects_config_mismatch() {
    let cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 1);
    let mut stop_cfg = cfg.clone();
    stop_cfg.stop_after_events = Some(50);
    let ckpt = run_timing(&stop_cfg).sim_checkpoint.unwrap();

    let mut other = base_cfg(Protocol::NSoftsync { n: 1 }, 1);
    other.lambda = 8;
    let mut engine = new_engine(&other);
    let err = engine.install_sim_checkpoint(&ckpt).unwrap_err().to_string();
    assert!(err.contains("belongs to config"), "mismatch must name both configs: {err}");
}
