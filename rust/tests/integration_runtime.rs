//! Runtime integration: load real artifacts, execute grad/eval graphs,
//! and cross-check the numerics (gradient direction, loss scale).
//!
//! Requires `make artifacts`. Tests are skipped (with a notice) when the
//! manifest is absent so `cargo test` stays green pre-AOT.

use rudra::harness::Workspace;
use rudra::params::FlatVec;

fn workspace() -> Option<Workspace> {
    match Workspace::open_default() {
        Ok(ws) => Some(ws),
        Err(e) => {
            eprintln!("skipping runtime integration (no artifacts): {e}");
            None
        }
    }
}

#[test]
fn manifest_and_init_agree() {
    let Some(ws) = workspace() else { return };
    let theta = ws.cnn_init().unwrap();
    assert_eq!(theta.len(), ws.manifest.cnn.params);
    assert!(theta.is_finite());
    assert!(theta.norm() > 0.0);
    assert_eq!(ws.train.classes, ws.manifest.data.classes);
    assert_eq!(ws.train.n, ws.manifest.data.train_n);
}

#[test]
fn grad_executes_and_descends() {
    let Some(ws) = workspace() else { return };
    let mu = 16;
    let exec = ws.cnn_grad(mu).unwrap();
    let mut theta = ws.cnn_init().unwrap();
    let mut sampler = rudra::data::sampler::BatchSampler::new(&ws.train, mu, 7, 0);

    // Fixed batch: repeated SGD steps must reduce its loss.
    let batch = sampler.next_batch();
    let first = exec.run_images(&theta, &batch.images, &batch.labels).unwrap();
    assert!(first.loss.is_finite());
    assert!(first.grads.is_finite());
    assert_eq!(first.grads.len(), theta.len());
    // initial loss ≈ ln(10) for 10-way softmax from random init
    assert!((1.0..5.0).contains(&first.loss), "initial loss {}", first.loss);

    let mut loss = first.loss;
    for _ in 0..10 {
        let out = exec.run_images(&theta, &batch.images, &batch.labels).unwrap();
        theta.axpy(-0.1, &out.grads);
        loss = out.loss;
    }
    assert!(
        loss < first.loss * 0.9,
        "SGD on a fixed batch must overfit it: {} -> {}",
        first.loss,
        loss
    );
}

#[test]
fn grad_batch_sizes_all_load() {
    let Some(ws) = workspace() else { return };
    for &mu in &ws.manifest.cnn.batch_sizes() {
        let exec = ws.cnn_grad(mu).unwrap();
        assert_eq!(exec.x_dims[0], mu);
    }
    assert!(ws.cnn_grad(999).is_err(), "unknown μ must fail cleanly");
}

#[test]
fn eval_scores_are_sane() {
    let Some(ws) = workspace() else { return };
    let eval = ws.cnn_eval().unwrap();
    let theta = ws.cnn_init().unwrap();
    use rudra::coordinator::engine_sim::Evaluator;
    let mut ev =
        rudra::stats::ImageEvaluator::new(&eval, &ws.test, ws.manifest.cnn.eval_batch);
    let (loss, err) = ev.eval(&theta).unwrap();
    // untrained 10-class model: error near 90%, loss near ln(10)
    assert!((70.0..=99.9).contains(&err), "untrained error {err}");
    assert!((1.5..4.0).contains(&loss), "untrained loss {loss}");
}

#[test]
fn grad_is_deterministic() {
    let Some(ws) = workspace() else { return };
    let exec = ws.cnn_grad(4).unwrap();
    let theta = ws.cnn_init().unwrap();
    let mut s = rudra::data::sampler::BatchSampler::new(&ws.train, 4, 3, 1);
    let b = s.next_batch();
    let a = exec.run_images(&theta, &b.images, &b.labels).unwrap();
    let c = exec.run_images(&theta, &b.images, &b.labels).unwrap();
    assert_eq!(a.loss, c.loss);
    assert_eq!(a.grads.data, c.grads.data);
}

#[test]
fn rejects_wrong_theta_length() {
    let Some(ws) = workspace() else { return };
    let exec = ws.cnn_grad(4).unwrap();
    let bad = FlatVec::zeros(10);
    let mut s = rudra::data::sampler::BatchSampler::new(&ws.train, 4, 3, 0);
    let b = s.next_batch();
    assert!(exec.run_images(&bad, &b.images, &b.labels).is_err());
}

#[test]
fn lm_grad_executes() {
    let Some(ws) = workspace() else { return };
    if ws.manifest.lm.is_none() {
        eprintln!("skipping LM runtime test (aot --skip-lm)");
        return;
    }
    let exec = ws.lm_grad().unwrap();
    let theta = ws.lm_init().unwrap();
    let mut s = rudra::data::corpus::WindowSampler::new(
        &ws.corpus,
        ws.manifest.lm_batch,
        ws.manifest.lm_seq,
        5,
        0,
    );
    let b = s.next_batch();
    let out = exec.run_tokens(&theta, &b.tokens, &b.targets).unwrap();
    assert!(out.loss.is_finite());
    // byte-LM from scratch: loss ≈ ln(256) ≈ 5.55
    assert!((4.0..7.0).contains(&out.loss), "initial LM loss {}", out.loss);
    assert!(out.grads.is_finite());
}
