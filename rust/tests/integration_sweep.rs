//! Parallel sweep executor: `jobs: k` grids must be bit-identical to
//! `jobs: 1` grids, per result field.
//!
//! Grid points derive *all* of their state from their index (seed,
//! provider, RNG streams), so [`run_indexed`] only ever decides which
//! host thread computes a point — never its inputs. These tests pin that
//! contract across the three protocol families × shard counts, including
//! a churn + heterogeneous-straggler point (the elastic and straggler
//! subsystems draw from their own named RNG streams, which is what keeps
//! them replayable off the main thread).

use rudra::coordinator::engine_sim::{run_sim, SimConfig, SimResult};
use rudra::coordinator::learner::MockProvider;
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::elastic::membership::ChurnSchedule;
use rudra::elastic::rescaler::RescalePolicy;
use rudra::harness::sweep::run_indexed;
use rudra::netsim::cost::ModelCost;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::straggler::hetero::HeteroSpec;

const N_PARAMS: usize = 4;

fn tiny_model() -> ModelCost {
    ModelCost {
        name: "tiny",
        flops_per_sample: 1.0e6,
        bytes: 1.0e3,
        samples_per_epoch: 64,
    }
}

/// The grid under test: (protocol, S) across the three protocol families
/// × S ∈ {1, 4}, plus a churn + hetero point. Each point's config is a
/// pure function of its index — the executor contract.
fn grid_configs() -> Vec<SimConfig> {
    let mut cfgs = Vec::new();
    for protocol in [Protocol::Hardsync, Protocol::NSoftsync { n: 2 }, Protocol::Async] {
        for shards in [1usize, 4] {
            let mut cfg =
                SimConfig::paper(protocol, Arch::Base, 4, 4, 2, tiny_model());
            cfg.seed = 11 + cfgs.len() as u64;
            cfg.shards = shards;
            cfgs.push(cfg);
        }
    }
    // The elastic + straggler point: a kill/rejoin cycle under μ·λ
    // rescale with a persistent 3× straggler. 4 epochs so the 0.009 s
    // rejoin is comfortably mid-run (the integration_elastic suite pins
    // that schedule/epoch pairing).
    let mut churny =
        SimConfig::paper(Protocol::NSoftsync { n: 2 }, Arch::Base, 4, 4, 4, tiny_model());
    churny.seed = 31;
    churny.shards = 4;
    churny.churn = ChurnSchedule::parse("kill:1@0.004,rejoin:1@0.009").unwrap();
    churny.rescale = RescalePolicy::MuLambdaConst;
    churny.hetero = HeteroSpec::parse("slow:0x3").unwrap();
    cfgs.push(churny);
    cfgs
}

fn run_point(cfg: &SimConfig) -> SimResult {
    let mut provider = MockProvider::new(vec![0.0; N_PARAMS]);
    run_sim(
        cfg,
        FlatVec::from_vec(vec![1.0, -2.0, 0.5, 3.0]),
        Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, N_PARAMS),
        LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128),
        Some(&mut provider),
        None,
    )
    .expect("grid point")
}

/// Everything `PointResult` is built from, pinned field by field. f64s
/// compare with `==`: bit-identical means bit-identical.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    sim_seconds: f64,
    updates: u64,
    events_processed: u64,
    theta: Vec<f32>,
    staleness_count: u64,
    staleness_max: u64,
    avg_staleness: f64,
    final_train_loss: f64,
    epochs: Vec<(usize, f64, f64, usize)>,
    shard_updates: Vec<u64>,
    churn_events: usize,
    recovery_secs: Vec<f64>,
    final_active_lambda: usize,
    dropped_gradients: u64,
    dropped_by_learner: Vec<u64>,
    learner_utilization: Vec<f64>,
    hetero_factors: Vec<f64>,
    root_bytes_in: f64,
    root_bytes_out: f64,
    comm_bytes_by_learner: Vec<f64>,
}

fn fingerprint(r: &SimResult) -> Fingerprint {
    Fingerprint {
        sim_seconds: r.sim_seconds,
        updates: r.updates,
        events_processed: r.events_processed,
        theta: r.theta.as_ref().expect("numeric run").data.clone(),
        staleness_count: r.staleness.count,
        staleness_max: r.staleness.max,
        avg_staleness: r.staleness.overall_avg(),
        final_train_loss: r.final_train_loss,
        epochs: r
            .epochs
            .iter()
            .map(|e| (e.epoch, e.sim_time, e.train_loss, e.active_lambda))
            .collect(),
        shard_updates: r.shard_updates.clone(),
        churn_events: r.churn.len(),
        recovery_secs: r.recovery_secs.clone(),
        final_active_lambda: r.final_active_lambda,
        dropped_gradients: r.dropped_gradients,
        dropped_by_learner: r.dropped_by_learner.clone(),
        learner_utilization: r.learner_utilization.clone(),
        hetero_factors: r.hetero_factors.clone(),
        root_bytes_in: r.root_bytes_in,
        root_bytes_out: r.root_bytes_out,
        comm_bytes_by_learner: r.comm_bytes_by_learner.clone(),
    }
}

#[test]
fn parallel_grid_is_bit_identical_to_serial_per_field() {
    let cfgs = grid_configs();
    let serial: Vec<Fingerprint> =
        run_indexed(1, cfgs.len(), |i| Ok(fingerprint(&run_point(&cfgs[i]))))
            .expect("serial grid");
    for jobs in [2usize, 4] {
        let parallel: Vec<Fingerprint> =
            run_indexed(jobs, cfgs.len(), |i| Ok(fingerprint(&run_point(&cfgs[i]))))
                .expect("parallel grid");
        assert_eq!(parallel.len(), serial.len(), "jobs={jobs}: grid order and length");
        for (i, (s, p)) in serial.iter().zip(parallel.iter()).enumerate() {
            assert_eq!(
                p, s,
                "jobs={jobs}: point {i} ({}) diverged from serial",
                cfgs[i].protocol.label()
            );
        }
    }
}

#[test]
fn parallel_grid_repeats_are_stable() {
    // Two identical parallel runs must agree with each other too (the
    // executor cannot leak cross-thread state into results).
    let cfgs = grid_configs();
    let a: Vec<Fingerprint> =
        run_indexed(4, cfgs.len(), |i| Ok(fingerprint(&run_point(&cfgs[i])))).unwrap();
    let b: Vec<Fingerprint> =
        run_indexed(4, cfgs.len(), |i| Ok(fingerprint(&run_point(&cfgs[i])))).unwrap();
    assert_eq!(a, b);
}

#[test]
fn churny_point_actually_exercises_the_elastic_path() {
    // Guard against the property test going vacuous: the churn + hetero
    // point must really kill/rejoin and really slow learner 0.
    let cfgs = grid_configs();
    let churny = cfgs.last().expect("grid has the churn point");
    let r = run_point(churny);
    assert!(r.churn.len() >= 2, "kill + rejoin must both fire, saw {}", r.churn.len());
    assert_eq!(r.recovery_secs.len(), 1, "one death→rejoin cycle");
    assert_eq!(r.hetero_factors, vec![3.0, 1.0, 1.0, 1.0]);
    assert_eq!(r.final_active_lambda, 4, "learner 1 is back by the end");
}
