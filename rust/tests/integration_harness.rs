//! Harness/config integration: workspace loading, config layering, and
//! a miniature grid sweep (artifacts required; skipped otherwise).

use rudra::config::{ModelKind, RunConfig};
use rudra::coordinator::protocol::Protocol;
use rudra::harness::sweep::Sweep;
use rudra::harness::Workspace;
use rudra::util::cli::Args;
use rudra::util::json::Json;

fn workspace() -> Option<Workspace> {
    match Workspace::open_default() {
        Ok(ws) => Some(ws),
        Err(e) => {
            eprintln!("skipping harness integration (no artifacts): {e}");
            None
        }
    }
}

#[test]
fn config_file_plus_cli_layering_end_to_end() {
    let dir = std::env::temp_dir().join("rudra_test_harness");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.json");
    std::fs::write(
        &path,
        r#"{"protocol": "hardsync", "mu": 32, "lambda": 8, "model": "cnn"}"#,
    )
    .unwrap();
    let mut cfg = RunConfig::default();
    cfg.apply_file(&path).unwrap();
    let args = Args::parse(
        ["--protocol", "2-softsync", "--epochs", "5"].iter().map(|s| s.to_string()),
        &[],
    )
    .unwrap();
    cfg.apply_args(&args).unwrap();
    assert_eq!(cfg.protocol, Protocol::NSoftsync { n: 2 });
    assert_eq!(cfg.mu, 32);
    assert_eq!(cfg.epochs, 5);
    assert_eq!(cfg.model, ModelKind::Cnn);
}

#[test]
fn workspace_cost_model_reflects_manifest() {
    let Some(ws) = workspace() else { return };
    let cost = ws.cnn_cost();
    assert_eq!(cost.bytes as usize, ws.manifest.cnn.params * 4);
    assert_eq!(cost.samples_per_epoch as usize, ws.manifest.data.train_n);
    assert!(cost.flops_per_sample > 1e5);
}

#[test]
fn mini_grid_produces_coherent_results() {
    let Some(ws) = workspace() else { return };
    let sweep = Sweep::new(&ws, 2);
    let results = sweep
        .run_grid(&[16], &[1, 4], |_| Protocol::NSoftsync { n: 1 })
        .unwrap();
    assert_eq!(results.len(), 2);
    // scale-out reduces simulated time on the paper geometry
    assert!(
        results[1].paper_sim_seconds < results[0].paper_sim_seconds,
        "λ=4 {} !< λ=1 {}",
        results[1].paper_sim_seconds,
        results[0].paper_sim_seconds
    );
    for r in &results {
        assert!(r.test_error_pct.is_finite());
        assert!(r.updates > 0);
    }
}

#[test]
fn manifest_env_override_is_respected() {
    // Pointing RUDRA_MANIFEST at nonsense must fail loudly, not fall back.
    let prev = std::env::var("RUDRA_MANIFEST").ok();
    std::env::set_var("RUDRA_MANIFEST", "/nonexistent/manifest.json");
    let r = Workspace::open_default();
    match prev {
        Some(v) => std::env::set_var("RUDRA_MANIFEST", v),
        None => std::env::remove_var("RUDRA_MANIFEST"),
    }
    assert!(r.is_err());
}
