//! Cluster-simulator integration: the timing-side claims of the paper
//! reproduced end-to-end through the event engine (no numerics needed).

use rudra::coordinator::engine_sim::{run_sim, SimConfig};
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::netsim::cost::ModelCost;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;

fn timing(cfg: &SimConfig) -> rudra::coordinator::engine_sim::SimResult {
    run_sim(
        cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.001), Modulation::Auto, 128),
        None,
        None,
    )
    .unwrap()
}

/// §5.4: the CIFAR10 baseline (μ=128, λ=1, hardsync) takes 22 392 s for
/// 140 epochs on the P775. Our calibrated simulator should land within
/// ~35% (one learner, no contention — pure compute model).
#[test]
fn cifar_baseline_time_matches_paper_scale() {
    let mut cfg = SimConfig::paper(
        Protocol::Hardsync,
        Arch::Base,
        128,
        1,
        140,
        ModelCost::cifar10(),
    );
    cfg.cluster.compute_jitter = 0.0;
    let r = timing(&cfg);
    let paper = 22_392.0;
    assert!(
        (r.sim_seconds / paper - 1.0).abs() < 0.35,
        "simulated {} vs paper {paper}",
        r.sim_seconds
    );
}

/// §5.5: ImageNet baseline (μ=256, λ=1) takes 54 h/epoch.
#[test]
fn imagenet_baseline_epoch_time_matches_paper_scale() {
    let mut cfg = SimConfig::paper(
        Protocol::Hardsync,
        Arch::Base,
        256,
        1,
        1,
        ModelCost::imagenet(),
    );
    cfg.cluster.compute_jitter = 0.0;
    let r = timing(&cfg);
    let hours = r.sim_seconds / 3600.0;
    assert!((hours / 54.0 - 1.0).abs() < 0.35, "simulated {hours} h vs paper 54 h");
}

/// Figure 8's qualitative content: hardsync speed-up < softsync speed-up,
/// and 1-softsync ≥ λ-softsync at small μ.
#[test]
fn fig8_speedup_ordering_at_small_mu() {
    let epochs = 2;
    let model = ModelCost::cifar10;
    let lambda = 16;
    let t = |protocol| {
        let mut cfg =
            SimConfig::paper(protocol, Arch::Base, 4, lambda, epochs, model());
        cfg.seed = 5;
        timing(&cfg).sim_seconds
    };
    let t_base = {
        let mut cfg = SimConfig::paper(
            Protocol::NSoftsync { n: 1 },
            Arch::Base,
            4,
            1,
            epochs,
            model(),
        );
        cfg.seed = 5;
        timing(&cfg).sim_seconds
    };
    let s_hard = t_base / t(Protocol::Hardsync);
    let s_soft1 = t_base / t(Protocol::NSoftsync { n: 1 });
    let s_softl = t_base / t(Protocol::NSoftsync { n: lambda });
    assert!(s_soft1 > s_hard, "1-softsync {s_soft1} vs hardsync {s_hard}");
    assert!(s_soft1 >= s_softl * 0.95, "1-softsync {s_soft1} vs λ-softsync {s_softl}");
    assert!(s_soft1 > lambda as f64 * 0.3, "scale-out should be material: {s_soft1}");
}

/// §3.3/Table 1 direction: on the adversarial workload the overlap ratio
/// must order base < adv < adv*.
#[test]
fn table1_overlap_ordering() {
    let model = ModelCost::adversarial_300mb;
    let overlap = |arch| {
        let mut cfg =
            SimConfig::paper(Protocol::NSoftsync { n: 1 }, arch, 4, 56, 1, model());
        cfg.max_updates = Some(40);
        cfg.seed = 9;
        timing(&cfg).overlap.overlap_pct()
    };
    let base = overlap(Arch::Base);
    let adv = overlap(Arch::Adv);
    let advstar = overlap(Arch::AdvStar);
    assert!(
        base < adv && adv < advstar,
        "overlap must order base({base:.1}) < adv({adv:.1}) < adv*({advstar:.1})"
    );
    assert!(advstar > 90.0, "adv* should nearly hide comm: {advstar:.1}");
    assert!(base < 40.0, "base should be comm-bound: {base:.1}");
}

/// Epoch time decreases monotonically with λ at fixed μ (Fig 6's time
/// axis: "training time reduces monotonically with λ").
#[test]
fn fig6_time_monotone_in_lambda() {
    let mut last = f64::INFINITY;
    for lambda in [1usize, 2, 4, 8, 16] {
        let mut cfg = SimConfig::paper(
            Protocol::Hardsync,
            Arch::Base,
            128,
            lambda,
            1,
            ModelCost::cifar10(),
        );
        cfg.cluster.compute_jitter = 0.0;
        let t = timing(&cfg).sim_seconds;
        assert!(t < last, "λ={lambda}: {t} !< {last}");
        last = t;
    }
}

/// Small μ costs more wall-clock than large μ at the same λ and epoch
/// budget (the GEMM-efficiency falloff; Fig 6's (0,4,1) observation).
#[test]
fn small_mu_slower_per_epoch() {
    let t = |mu| {
        let mut cfg = SimConfig::paper(
            Protocol::Hardsync,
            Arch::Base,
            mu,
            1,
            1,
            ModelCost::cifar10(),
        );
        cfg.cluster.compute_jitter = 0.0;
        timing(&cfg).sim_seconds
    };
    assert!(t(4) > 1.5 * t(128), "μ=4 {} vs μ=128 {}", t(4), t(128));
}
