//! Protocol-level integration over the virtual-time engine with the mock
//! quadratic provider: verifies the paper's §5.1 staleness claims and the
//! Figure 5 learning-rate-modulation effect at the optimizer level,
//! without needing artifacts.

use rudra::coordinator::engine_sim::{run_sim, SimConfig, SimResult};
use rudra::coordinator::learner::MockProvider;
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::netsim::cost::ModelCost;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;

fn tiny_model(samples_per_epoch: u64) -> ModelCost {
    ModelCost { name: "tiny", flops_per_sample: 1.0e6, bytes: 1.0e3, samples_per_epoch }
}

fn run(
    protocol: Protocol,
    lambda: usize,
    epochs: usize,
    base_lr: f64,
    modulation: Modulation,
    dim: usize,
) -> SimResult {
    let mut cfg =
        SimConfig::paper(protocol, Arch::Base, 4, lambda, epochs, tiny_model(256));
    cfg.seed = 17;
    let theta0 = FlatVec::from_vec((0..dim).map(|i| (i as f32 % 5.0) - 2.0).collect());
    let opt = Optimizer::new(OptimizerKind::Sgd, 0.0, dim);
    let lr = LrPolicy::new(Schedule::constant(base_lr), modulation, 128);
    let mut provider = MockProvider::new(vec![0.0; dim]);
    run_sim(&cfg, theta0, opt, lr, Some(&mut provider), None).unwrap()
}

/// §5.1, Figure 4(a): 1-softsync and 2-softsync keep ⟨σ⟩ near 1 and 2.
#[test]
fn fig4a_softsync_staleness_tracks_n() {
    let lambda = 16;
    for n in [1usize, 2] {
        let r = run(
            Protocol::NSoftsync { n },
            lambda,
            4,
            0.02,
            Modulation::StalenessReciprocal,
            8,
        );
        let avg = r.staleness.overall_avg();
        assert!(
            (n as f64 * 0.3..=n as f64 * 2.2).contains(&avg),
            "{n}-softsync ⟨σ⟩ = {avg}, expected ≈ {n}"
        );
    }
}

/// §5.1, Figure 4(b): λ-softsync has ⟨σ⟩ ≈ λ with a bounded tail
/// (P[σ > 2n] < 1e-4 in the paper; we assert a generous version).
#[test]
fn fig4b_lambda_softsync_staleness_bounded() {
    let lambda = 16;
    let r = run(
        Protocol::NSoftsync { n: lambda },
        lambda,
        6,
        0.005,
        Modulation::StalenessReciprocal,
        8,
    );
    let avg = r.staleness.overall_avg();
    assert!(
        (lambda as f64 * 0.4..=lambda as f64 * 1.8).contains(&avg),
        "λ-softsync ⟨σ⟩ = {avg}, expected ≈ {lambda}"
    );
    let tail = r.staleness.frac_exceeding(2 * lambda as u64);
    assert!(tail < 0.02, "P[σ > 2n] = {tail} too heavy");
}

/// Figure 5's mechanism at the optimizer level: with λ-softsync and a
/// step size at the hardsync-stable limit, unmodulated updates diverge
/// while α/n converges. (The full CNN version is the fig5 bench.)
#[test]
fn fig5_modulation_rescues_convergence() {
    let lambda = 16;
    // On the quadratic bowl, plain SGD is stable for α < 2; with ⟨σ⟩ ≈ λ
    // stale updates the effective multiplier blows past stability.
    let diverged = run(
        Protocol::NSoftsync { n: lambda },
        lambda,
        4,
        1.6,
        Modulation::None,
        8,
    );
    let rescued = run(
        Protocol::NSoftsync { n: lambda },
        lambda,
        4,
        1.6,
        Modulation::StalenessReciprocal,
        8,
    );
    let d_norm = diverged.theta.unwrap().norm();
    let r_norm = rescued.theta.unwrap().norm();
    assert!(
        !d_norm.is_finite() || d_norm > 10.0,
        "unmodulated stale run should diverge (|θ| = {d_norm})"
    );
    assert!(r_norm < 2.0, "α/⟨σ⟩ run should converge (|θ| = {r_norm})");
}

/// Hardsync with the √(λμ/B) rule stays stable as λ grows.
#[test]
fn hardsync_sqrt_rule_stable_scaleout() {
    for lambda in [1usize, 4, 16] {
        let r = run(Protocol::Hardsync, lambda, 3, 0.3, Modulation::HardsyncSqrt, 8);
        let norm = r.theta.unwrap().norm();
        assert!(norm.is_finite() && norm < 4.0, "λ={lambda}: |θ| = {norm}");
        assert_eq!(r.staleness.max, 0);
    }
}

/// Async (= λ-softsync) applies one gradient per update: update count
/// must equal total pushes.
#[test]
fn async_update_count_matches_pushes() {
    let r = run(Protocol::Async, 8, 2, 0.01, Modulation::StalenessReciprocal, 4);
    assert_eq!(r.staleness.per_update_avg.len() as u64, r.updates);
    // every update folded exactly one gradient
    assert_eq!(r.staleness.count, r.updates);
}

/// Footnote-3 extension: per-gradient 1/(σᵢ+1) scaling also rescues the
/// λ-softsync run that diverges unmodulated (like Fig 5, but finer
/// grained — stale gradients are damped individually).
#[test]
fn per_gradient_modulation_rescues_convergence() {
    // α₀ = 1.2: far beyond the delayed-feedback stability edge when
    // unmodulated (σ ≈ 16 requires α ≲ 0.1), safely inside it once each
    // gradient is damped by 1/(σᵢ+1) → α_eff ≈ 0.07.
    let lambda = 16;
    let diverged = run(
        Protocol::NSoftsync { n: lambda },
        lambda,
        4,
        1.2,
        Modulation::None,
        8,
    );
    let rescued = run(
        Protocol::NSoftsync { n: lambda },
        lambda,
        4,
        1.2,
        Modulation::PerGradient,
        8,
    );
    let d = diverged.theta.unwrap().norm();
    let r = rescued.theta.unwrap().norm();
    assert!(!d.is_finite() || d > 10.0, "unmodulated should diverge: {d}");
    assert!(r < 2.0, "per-gradient modulation should converge: {r}");
}

/// Future-work #1 (chaotic systems): straggler injection produces the
/// Downpour-style staleness tails the homogeneous cluster never shows,
/// and σ stays bounded by the in-flight limit rather than 2n.
#[test]
fn chaotic_cluster_fattens_staleness_tail() {
    let lambda = 8;
    let mk = |chaotic: bool| {
        let mut cfg = SimConfig::paper(
            Protocol::NSoftsync { n: lambda },
            Arch::Base,
            4,
            lambda,
            4,
            tiny_model(256),
        );
        cfg.seed = 21;
        if chaotic {
            cfg.cluster = rudra::netsim::cluster::ClusterSpec::chaotic();
        }
        let mut provider = MockProvider::new(vec![0.0; 4]);
        run_sim(
            &cfg,
            FlatVec::zeros(4),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 4),
            LrPolicy::new(Schedule::constant(0.001), Modulation::StalenessReciprocal, 128),
            Some(&mut provider),
            None,
        )
        .unwrap()
    };
    let calm = mk(false);
    let chaos = mk(true);
    assert!(
        chaos.staleness.max > calm.staleness.max,
        "stragglers must fatten the σ tail: {} vs {}",
        chaos.staleness.max,
        calm.staleness.max
    );
}

/// The three architectures agree on protocol semantics: same updates for
/// the same epoch budget (timing differs, math doesn't diverge wildly).
#[test]
fn architectures_preserve_update_budget() {
    let mut results = vec![];
    for arch in [Arch::Base, Arch::Adv, Arch::AdvStar] {
        let mut cfg =
            SimConfig::paper(Protocol::NSoftsync { n: 1 }, arch, 4, 8, 2, tiny_model(256));
        cfg.seed = 3;
        let mut provider = MockProvider::new(vec![0.0; 4]);
        let r = run_sim(
            &cfg,
            FlatVec::from_vec(vec![1.0, 1.0, 1.0, 1.0]),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 4),
            LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128),
            Some(&mut provider),
            None,
        )
        .unwrap();
        results.push((arch, r.updates));
    }
    let base_updates = results[0].1;
    for (arch, updates) in &results {
        // Epoch accounting is sample-driven, so update totals match
        // across architectures for the same protocol.
        assert_eq!(*updates, base_updates, "{arch:?}");
    }
}
