//! Network chaos engineering: the fault plane + reliability layer end to
//! end through the virtual-time engine.
//!
//! Covers the PR's acceptance properties:
//! * a quiet `faults` spec is bit-identical to the legacy path across the
//!   three protocol families × root shards S ∈ {1, 4};
//! * a duplicate-heavy fabric never double-accumulates a gradient — the
//!   training trajectory matches the clean run exactly while the dedup
//!   ledger shows the duplicates arriving and being rejected;
//! * 1-softsync staleness stays within the paper's σ ≤ 2n envelope under
//!   5 % message loss;
//! * a healed rack partition ends in membership eviction + revival for
//!   barrier protocols (hardsync, backup-sync), never a deadlock;
//! * a faulted run stops at event k and resumes bit-identically,
//!   fault-plane RNG, dedup windows, and ledger included.

use rudra::coordinator::engine_sim::{run_sim, SimConfig, SimEngine, SimResult};
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::elastic::membership::{ChurnKind, ChurnSchedule};
use rudra::elastic::rescaler::RescalePolicy;
use rudra::netsim::cluster::ClusterSpec;
use rudra::netsim::cost::{LearnerCompute, ModelCost};
use rudra::netsim::faults::FaultSpec;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::straggler::adaptive::AdaptiveSpec;
use rudra::straggler::hetero::HeteroSpec;

fn tiny_model(samples_per_epoch: u64) -> ModelCost {
    ModelCost { name: "tiny", flops_per_sample: 1.0e6, bytes: 1.0e3, samples_per_epoch }
}

fn quiet_cluster() -> ClusterSpec {
    ClusterSpec { compute_jitter: 0.0, straggler_prob: 0.0, ..ClusterSpec::p775() }
}

fn base_cfg(protocol: Protocol, shards: usize) -> SimConfig {
    SimConfig {
        protocol,
        arch: Arch::Base,
        mu: 4,
        lambda: 6,
        epochs: 2,
        seed: 23,
        cluster: quiet_cluster(),
        compute: LearnerCompute::p775(),
        model: tiny_model(240),
        shards,
        eval_each_epoch: false,
        max_updates: None,
        churn: ChurnSchedule::none(),
        rescale: RescalePolicy::None,
        checkpoint_every_updates: 0,
        hetero: HeteroSpec::parse("none").unwrap(),
        adaptive: AdaptiveSpec::none(),
        compress: rudra::comm::codec::CodecSpec::None,
        stop_after_events: None,
        sim_checkpoint_path: None,
        trace: false,
        trace_path: None,
        collect_metrics: false,
        metrics_every: None,
        profile: false,
        faults: FaultSpec::none(),
    }
}

fn run_timing(cfg: &SimConfig) -> SimResult {
    run_sim(
        cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
        None,
        None,
    )
    .unwrap()
}

fn new_engine(cfg: &SimConfig) -> SimEngine<'_> {
    SimEngine::new(
        cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
        None,
        None,
    )
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Compare the trajectory-observable SimResult fields bit for bit
/// (floats by IEEE 754 bit pattern, not tolerance). Excludes the fields
/// that depend on the exact *event stream* rather than the trajectory:
/// `events_processed`, `sim_seconds`, and `learner_utilization` — the
/// run's horizon is the timestamp of the first event popped after the
/// final update, so a trailing no-op duplicate delivery can legally
/// shift it without touching any training-visible state.
fn assert_updates_same(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.updates, b.updates, "{ctx}: updates");
    assert_eq!(a.shard_updates, b.shard_updates, "{ctx}: shard_updates");
    assert_eq!(a.staleness.totals(), b.staleness.totals(), "{ctx}: staleness totals");
    assert_eq!(a.staleness.max, b.staleness.max, "{ctx}: staleness max");
    assert_eq!(a.staleness.histogram, b.staleness.histogram, "{ctx}: staleness histogram");
    assert_eq!(
        bits(&a.staleness.per_update_avg),
        bits(&b.staleness.per_update_avg),
        "{ctx}: staleness series"
    );
    assert_eq!(a.epochs.len(), b.epochs.len(), "{ctx}: epoch count");
    for (ea, eb) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(ea.epoch, eb.epoch, "{ctx}: epoch index");
        assert_eq!(ea.sim_time.to_bits(), eb.sim_time.to_bits(), "{ctx}: epoch time");
        assert_eq!(ea.active_lambda, eb.active_lambda, "{ctx}: epoch λ_active");
    }
    assert_eq!(format!("{:?}", a.churn), format!("{:?}", b.churn), "{ctx}: churn log");
    assert_eq!(bits(&a.recovery_secs), bits(&b.recovery_secs), "{ctx}: recovery");
    assert_eq!(format!("{:?}", a.adaptive), format!("{:?}", b.adaptive), "{ctx}: adaptive");
    assert_eq!(format!("{:?}", a.overlap), format!("{:?}", b.overlap), "{ctx}: overlap");
    assert_eq!(a.final_active_lambda, b.final_active_lambda, "{ctx}: λ_active");
    assert_eq!(a.checkpoints_taken, b.checkpoints_taken, "{ctx}: checkpoints");
    assert_eq!(a.dropped_gradients, b.dropped_gradients, "{ctx}: dropped");
    assert_eq!(a.dropped_by_learner, b.dropped_by_learner, "{ctx}: dropped by learner");
    assert_eq!(bits(&a.hetero_factors), bits(&b.hetero_factors), "{ctx}: hetero factors");
    assert_eq!(a.root_bytes_in.to_bits(), b.root_bytes_in.to_bits(), "{ctx}: root bytes in");
    assert_eq!(a.root_bytes_out.to_bits(), b.root_bytes_out.to_bits(), "{ctx}: root bytes out");
    assert_eq!(
        bits(&a.comm_bytes_by_learner),
        bits(&b.comm_bytes_by_learner),
        "{ctx}: comm bytes"
    );
}

/// The strict form: identical event streams must also agree on the event
/// count, the horizon, the per-learner utilization derived from it, and
/// the rescale log (an armed fault plane makes the run elastic, which
/// books a t = 0 active-set normalization record a legacy run lacks —
/// comparable only between two runs armed the same way).
fn assert_trajectory_same(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_updates_same(a, b, ctx);
    assert_eq!(format!("{:?}", a.rescales), format!("{:?}", b.rescales), "{ctx}: rescales");
    assert_eq!(a.events_processed, b.events_processed, "{ctx}: events_processed");
    assert_eq!(a.sim_seconds.to_bits(), b.sim_seconds.to_bits(), "{ctx}: sim_seconds");
    assert_eq!(
        bits(&a.learner_utilization),
        bits(&b.learner_utilization),
        "{ctx}: utilization"
    );
}

const FAMILIES: [Protocol; 3] =
    [Protocol::Hardsync, Protocol::NSoftsync { n: 1 }, Protocol::BackupSync { b: 1 }];

/// `faults none` takes the exact legacy code path: a quiet spec — even
/// one that sets the retry knobs, which have nothing to retry — must
/// reproduce the default run bit for bit, including `events_processed`,
/// across the three protocol families and root shards S ∈ {1, 4}.
#[test]
fn quiet_spec_is_bit_identical_across_protocols_and_shards() {
    for protocol in FAMILIES {
        for shards in [1usize, 4] {
            let cfg = base_cfg(protocol, shards);
            let baseline = run_timing(&cfg);
            assert_eq!(baseline.epochs.len(), 2, "baseline completes");
            let mut quiet_cfg = cfg.clone();
            quiet_cfg.faults = FaultSpec::parse("retries:3,rto:0.5").unwrap();
            assert!(quiet_cfg.faults.is_quiet());
            let quiet = run_timing(&quiet_cfg);
            let ctx = format!("{protocol:?} S={shards} quiet");
            assert_trajectory_same(&baseline, &quiet, &ctx);
            assert!(baseline.faults.is_none(), "{ctx}: legacy run carries no ledger");
            assert!(quiet.faults.is_none(), "{ctx}: quiet run skips the fault plane");
        }
    }
}

/// The idempotency property: under a duplicate-heavy fabric (40 % of
/// deliveries re-delivered) every duplicate bounces off a receiver dedup
/// window, so the training trajectory — updates, virtual time, staleness,
/// byte flows — is bit-identical to the clean run. Only the event count
/// (no-op dup deliveries) and the ledger differ.
#[test]
fn dup_heavy_fabric_never_double_applies() {
    for protocol in FAMILIES {
        for shards in [1usize, 4] {
            let cfg = base_cfg(protocol, shards);
            let clean = run_timing(&cfg);
            let mut dup_cfg = cfg.clone();
            dup_cfg.faults = FaultSpec::parse("dup:0.4").unwrap();
            let duped = run_timing(&dup_cfg);
            let ctx = format!("{protocol:?} S={shards} dup:0.4");
            assert_updates_same(&clean, &duped, &ctx);
            let st = duped.faults.as_ref().expect("armed run must carry the ledger");
            assert!(st.balances(), "{ctx}: conservation law: {st:?}");
            assert!(st.dups_injected > 0, "{ctx}: dup:0.4 must inject duplicates");
            assert!(st.dedup_dropped > 0, "{ctx}: duplicates must be rejected");
            assert!(
                st.dedup_dropped <= st.dups_injected,
                "{ctx}: cannot reject more dups than were injected: {st:?}"
            );
            assert!(
                duped.events_processed >= clean.events_processed,
                "{ctx}: dup deliveries only add events"
            );
            assert_eq!(st.retransmits, 0, "{ctx}: nothing to retransmit without loss");
            // An armed plane makes the run elastic, which books one t = 0
            // active-set normalization; no *mid-run* rescale may appear.
            assert!(clean.rescales.is_empty(), "{ctx}: clean run books no rescale");
            assert!(
                duped.rescales.iter().all(|r| r.at == 0.0),
                "{ctx}: duplicates must never trigger a mid-run rescale: {:?}",
                duped.rescales
            );
        }
    }
}

/// 5 % message loss with the retry chain live: 1-softsync completes and
/// average staleness stays inside the paper's σ ≤ 2n envelope (n = 1) —
/// retransmissions delay gradients, they do not break the protocol. The
/// same seed + spec replays bit-identically, ledger included.
#[test]
fn softsync_staleness_bounded_under_loss_and_replays_exactly() {
    let mut cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 1);
    cfg.lambda = 8;
    cfg.faults = FaultSpec::parse("loss:0.05").unwrap();
    let r = run_timing(&cfg);
    assert_eq!(r.epochs.len(), 2, "lossy run completes");
    assert!(r.updates > 0);
    let avg = r.staleness.overall_avg();
    assert!(avg <= 2.0, "1-softsync ⟨σ⟩ must stay ≤ 2n = 2 under 5% loss, got {avg}");
    let st = r.faults.as_ref().expect("armed run must carry the ledger");
    assert!(st.balances(), "conservation law: {st:?}");
    assert!(st.retransmits > 0, "5% loss must force retransmissions");
    assert_eq!(
        st.retransmits,
        st.retransmits_by.iter().sum::<u64>(),
        "per-learner attribution must total: {st:?}"
    );
    assert!(st.retry_bytes > 0.0, "retransmissions must book byte overhead");
    assert_eq!(st.exhausted, 0, "0.05^7 exhaustion is astronomically unlikely: {st:?}");

    let replay = run_timing(&cfg);
    assert_trajectory_same(&r, &replay, "loss:0.05 replay");
    assert_eq!(r.faults, replay.faults, "replay: fault ledger");
}

/// A rack partition against a barrier protocol: the cut-off learners
/// exhaust their retry budgets and take the Suspect → Dead membership
/// path (the run keeps making progress on the surviving quorum), then
/// revive when the window heals. No deadlock, and the run ends back at
/// full strength.
#[test]
fn healed_partition_evicts_then_revives_instead_of_deadlocking() {
    for protocol in [Protocol::Hardsync, Protocol::BackupSync { b: 1 }] {
        let cfg = base_cfg(protocol, 1);
        let clean = run_timing(&cfg);
        let t = clean.sim_seconds;
        assert!(t > 0.0);
        // Cut the upper rack (learners 3-5) for the middle third of the
        // clean run's duration; a tight retry budget makes the eviction
        // land well inside the window.
        let spec = format!("partition:rack0-rack1@{}s+{}s,retries:2", t / 4.0, t / 3.0);
        let mut chaos_cfg = cfg.clone();
        chaos_cfg.faults = FaultSpec::parse(&spec).unwrap();
        let r = run_timing(&chaos_cfg);
        let ctx = format!("{protocol:?} {spec}");
        assert_eq!(r.epochs.len(), 2, "{ctx}: partitioned run must still complete");
        assert!(r.updates > 0, "{ctx}");
        let st = r.faults.as_ref().expect("armed run must carry the ledger");
        assert!(st.balances(), "{ctx}: conservation law: {st:?}");
        assert!(st.exhausted > 0, "{ctx}: the partition must exhaust retry budgets");
        assert!(
            r.churn.iter().any(|c| matches!(c.kind, ChurnKind::Suspect)),
            "{ctx}: eviction goes through Suspect: {:?}",
            r.churn
        );
        assert!(
            r.churn.iter().any(|c| matches!(c.kind, ChurnKind::Kill)),
            "{ctx}: retry exhaustion must reach the Dead phase: {:?}",
            r.churn
        );
        assert!(
            r.churn.iter().any(|c| matches!(c.kind, ChurnKind::Rejoin)),
            "{ctx}: the heal must revive the partition's victims: {:?}",
            r.churn
        );
        assert_eq!(
            r.final_active_lambda, cfg.lambda,
            "{ctx}: all victims revive once the window heals"
        );
        assert!(!r.recovery_secs.is_empty(), "{ctx}: downtime must be recorded");
    }
}

/// Stop-at-event-k + resume of a *faulted* run is bit-identical to the
/// uninterrupted one: the checkpoint carries the fault plane's RNG
/// stream, every dedup window, in-flight retry bookkeeping, and the
/// accounting ledger across the cut.
#[test]
fn faulted_run_stop_resume_is_bit_identical() {
    for shards in [1usize, 4] {
        let mut cfg = base_cfg(Protocol::NSoftsync { n: 1 }, shards);
        cfg.faults = FaultSpec::parse("loss:0.05,dup:0.05,reorder:0.05,retries:3").unwrap();
        let full = run_timing(&cfg);
        assert_eq!(full.epochs.len(), 2, "faulted baseline completes");
        let st = full.faults.as_ref().expect("armed run must carry the ledger");
        assert!(st.balances(), "S={shards}: conservation law: {st:?}");
        assert!(st.dups_injected > 0 && st.retransmits > 0, "S={shards}: chaos fired: {st:?}");
        for k in [full.events_processed / 4, (3 * full.events_processed) / 4] {
            let k = k.max(1);
            let ctx = format!("faulted S={shards} k={k}");
            let mut stop_cfg = cfg.clone();
            stop_cfg.stop_after_events = Some(k);
            let stopped = run_timing(&stop_cfg);
            assert_eq!(stopped.events_processed, k, "{ctx}: stop lands exactly at k");
            let ckpt =
                stopped.sim_checkpoint.expect("mid-flight stop must capture a checkpoint");
            let mut engine = new_engine(&cfg);
            engine.install_sim_checkpoint(&ckpt).unwrap();
            let resumed = engine.run().unwrap();
            assert_trajectory_same(&full, &resumed, &ctx);
            assert_eq!(full.faults, resumed.faults, "{ctx}: fault ledger survives the cut");
        }
    }
}
