//! End-to-end training integration: real artifacts through both engines,
//! verifying the headline training behaviour the benches then quantify.
//! Skipped (with a notice) when artifacts are absent.

use rudra::config::RunConfig;
use rudra::coordinator::engine_live::{run_live, LiveConfig};
use rudra::coordinator::protocol::Protocol;
use rudra::harness::providers::{ComputeService, ServiceProvider};
use rudra::harness::sweep::Sweep;
use rudra::harness::Workspace;
use rudra::params::optimizer::Optimizer;

fn workspace() -> Option<Workspace> {
    match Workspace::open_default() {
        Ok(ws) => Some(ws),
        Err(e) => {
            eprintln!("skipping train integration (no artifacts): {e}");
            None
        }
    }
}

/// Short real training run through the virtual-time engine: error must
/// drop well below chance (90%) within a few epochs.
#[test]
fn sim_engine_trains_below_chance() {
    let Some(ws) = workspace() else { return };
    let mut sweep = Sweep::new(&ws, 3);
    sweep.eval_each_epoch = true;
    let cfg = RunConfig {
        protocol: Protocol::NSoftsync { n: 1 },
        mu: 16,
        lambda: 4,
        epochs: 3,
        ..RunConfig::default()
    };
    let p = sweep.run_point(&cfg).unwrap();
    // chance = 90% on the near-uniform 10-class benchmark; 3 epochs of
    // the reduced workload lands in the low 70s.
    assert!(
        p.test_error_pct < 82.0,
        "3 epochs should beat chance clearly: {}%",
        p.test_error_pct
    );
    assert!(p.train_loss < 2.28, "train loss {} should be below ln(10)", p.train_loss);
    assert!(p.avg_staleness < 3.0);
    assert!(p.sim_seconds > 0.0 && p.paper_sim_seconds > 0.0);
    // epoch stats carry eval series for Fig 5/9-style curves
    assert_eq!(p.epochs.len(), 3);
    assert!(p.epochs.iter().all(|e| e.test_error_pct.is_some()));
}

/// Hardsync and 1-softsync agree on accuracy at matched μλ within a
/// tolerance (Table 2/3's core claim) on a reduced budget.
#[test]
fn protocols_agree_at_matched_mulambda() {
    let Some(ws) = workspace() else { return };
    let sweep = Sweep::new(&ws, 3);
    let mut errs = vec![];
    for protocol in [Protocol::Hardsync, Protocol::NSoftsync { n: 1 }] {
        let cfg = RunConfig {
            protocol,
            mu: 8,
            lambda: 4,
            epochs: 3,
            ..RunConfig::default()
        };
        errs.push(sweep.run_point(&cfg).unwrap().test_error_pct);
    }
    let gap = (errs[0] - errs[1]).abs();
    assert!(
        gap < 15.0,
        "hardsync {} vs 1-softsync {} diverge too much at matched μλ",
        errs[0],
        errs[1]
    );
}

/// The live engine (real threads + compute service) completes a short
/// run and also beats chance.
#[test]
fn live_engine_trains_below_chance() {
    let Some(ws) = workspace() else { return };
    let manifest_path = std::env::var("RUDRA_MANIFEST")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| rudra::runtime::Manifest::default_path());
    let mu = 16;
    let lambda = 3;
    let service = ComputeService::start_cnn(manifest_path, mu).unwrap();
    let train = std::sync::Arc::new(ws.train.clone());
    let providers: Vec<Box<dyn rudra::coordinator::learner::GradProvider + Send>> = (0
        ..lambda)
        .map(|id| {
            Box::new(ServiceProvider::new(&service, train.clone(), mu, 11, id))
                as Box<dyn rudra::coordinator::learner::GradProvider + Send>
        })
        .collect();
    let cfg = RunConfig::default();
    let live_cfg = LiveConfig {
        protocol: Protocol::NSoftsync { n: 1 },
        mu,
        lambda,
        epochs: 2,
        samples_per_epoch: ws.train.n as u64,
        shards: 1,
        log_every: 0,
        elastic: None,
        compress: rudra::comm::codec::CodecSpec::None,
        checkpoint_every: 0,
        collect_metrics: false,
        trace: false,
        metrics_every: None,
        profile: false,
        faults: rudra::netsim::faults::FaultSpec::none(),
    };
    let theta0 = ws.cnn_init().unwrap();
    let optimizer = Optimizer::new(cfg.optimizer, 0.0, theta0.len());
    let r = run_live(&live_cfg, theta0, optimizer, cfg.lr_policy(), providers).unwrap();
    assert!(r.updates > 0);
    assert!(r.theta.is_finite());

    use rudra::coordinator::engine_sim::Evaluator;
    let eval = ws.cnn_eval().unwrap();
    let mut ev =
        rudra::stats::ImageEvaluator::new(&eval, &ws.test, ws.manifest.cnn.eval_batch);
    let (_, err) = ev.eval(&r.theta).unwrap();
    assert!(err < 80.0, "live 2-epoch error {err}%");
}

/// Warm-starting (§5.5) produces a different (and not worse) start.
#[test]
fn warmstart_path_works() {
    let Some(ws) = workspace() else { return };
    let sweep = Sweep::new(&ws, 2);
    let cfg = RunConfig {
        protocol: Protocol::NSoftsync { n: 4 },
        mu: 16,
        lambda: 4,
        epochs: 2,
        warmstart_epochs: 1,
        ..RunConfig::default()
    };
    let p = sweep.run_point(&cfg).unwrap();
    assert!(p.test_error_pct < 80.0, "warmstarted error {}%", p.test_error_pct);
}
