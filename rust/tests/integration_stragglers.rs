//! Straggler-subsystem integration: heterogeneous learner speeds, the
//! backup-sync protocol, and the adaptive-n controller, end to end
//! through the virtual-time engine on a zero-jitter cluster (every
//! trajectory exactly reproducible).

use rudra::coordinator::engine_sim::{run_sim, SimConfig, SimResult};
use rudra::coordinator::learner::MockProvider;
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::elastic::membership::ChurnSchedule;
use rudra::elastic::rescaler::RescalePolicy;
use rudra::netsim::cluster::ClusterSpec;
use rudra::netsim::cost::{LearnerCompute, ModelCost};
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::straggler::adaptive::AdaptiveSpec;
use rudra::straggler::hetero::HeteroSpec;

const DIM: usize = 4;

fn tiny_model(samples_per_epoch: u64) -> ModelCost {
    ModelCost { name: "tiny", flops_per_sample: 1.0e6, bytes: 1.0e3, samples_per_epoch }
}

fn quiet_cluster() -> ClusterSpec {
    ClusterSpec { compute_jitter: 0.0, straggler_prob: 0.0, ..ClusterSpec::p775() }
}

fn straggler_cfg(
    protocol: Protocol,
    mu: usize,
    lambda: usize,
    epochs: usize,
    samples_per_epoch: u64,
    hetero: &str,
) -> SimConfig {
    SimConfig {
        protocol,
        arch: Arch::Base,
        mu,
        lambda,
        epochs,
        seed: 11,
        cluster: quiet_cluster(),
        compute: LearnerCompute::p775(),
        model: tiny_model(samples_per_epoch),
        shards: 1,
        eval_each_epoch: false,
        max_updates: None,
        churn: ChurnSchedule::none(),
        rescale: RescalePolicy::None,
        checkpoint_every_updates: 0,
        hetero: HeteroSpec::parse(hetero).unwrap(),
        adaptive: AdaptiveSpec::none(),
        compress: rudra::comm::codec::CodecSpec::None,
        stop_after_events: None,
        sim_checkpoint_path: None,
        trace: false,
        trace_path: None,
        collect_metrics: false,
        metrics_every: None,
        profile: false,
        faults: rudra::netsim::faults::FaultSpec::none(),
    }
}

fn run_numeric(cfg: &SimConfig) -> SimResult {
    let mut provider = MockProvider::new(vec![0.0; DIM]);
    run_sim(
        cfg,
        FlatVec::from_vec(vec![1.0, -2.0, 0.5, 3.0]),
        Optimizer::new(OptimizerKind::Sgd, 0.0, DIM),
        LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
        Some(&mut provider),
        None,
    )
    .unwrap()
}

fn run_timing(cfg: &SimConfig) -> SimResult {
    run_sim(
        cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
        None,
        None,
    )
    .unwrap()
}

/// CI straggler smoke (fast): 2-epoch sim with a sampled lognormal
/// heterogeneity plus one hard 4× straggler under `backup:1` — the whole
/// subsystem end to end in milliseconds of virtual time.
#[test]
fn straggler_smoke() {
    let cfg = straggler_cfg(
        Protocol::BackupSync { b: 1 },
        4,
        6,
        2,
        240,
        "lognormal:0.2,slow:0x4",
    );
    let r = run_numeric(&cfg);
    assert_eq!(r.epochs.len(), 2, "completed");
    assert_eq!(r.staleness.max, 0, "backup-sync folds only fresh gradients");
    assert!(r.dropped_gradients > 0, "the 4× straggler must lose rounds");
    assert_eq!(r.dropped_by_learner.iter().sum::<u64>(), r.dropped_gradients);
    assert!(
        r.dropped_by_learner[0] > 0,
        "drops should land on the slow learner: {:?}",
        r.dropped_by_learner
    );
    assert!(
        r.hetero_factors[0] > 2.0,
        "the explicit 4× multiplies the sampled lognormal draw: {:?}",
        r.hetero_factors
    );
    assert!(r.theta.unwrap().is_finite());
}

/// The acceptance scenario: a single 10× straggler at λ = 8. Hardsync's
/// barrier degrades toward the straggler's speed; backup:1 closes rounds
/// without it and recovers ≥ 80% of the *ideal* (no-straggler) hardsync
/// epoch time (the ~12% tax is the smaller per-round quota: λ − 1 of λ
/// gradients count toward epoch samples).
#[test]
fn backup_sync_recovers_straggler_epoch_time() {
    let samples = 1600; // 50 ideal hardsync rounds per epoch at μ=4, λ=8
    let ideal = run_timing(&straggler_cfg(Protocol::Hardsync, 4, 8, 2, samples, "none"));
    let hard10 =
        run_timing(&straggler_cfg(Protocol::Hardsync, 4, 8, 2, samples, "slow:0x10"));
    let backup10 = run_timing(&straggler_cfg(
        Protocol::BackupSync { b: 1 },
        4,
        8,
        2,
        samples,
        "slow:0x10",
    ));
    assert!(
        hard10.sim_seconds > 4.0 * ideal.sim_seconds,
        "hardsync must degrade toward the 10× straggler: {} vs ideal {}",
        hard10.sim_seconds,
        ideal.sim_seconds
    );
    let recovery = ideal.sim_seconds / backup10.sim_seconds;
    assert!(
        recovery >= 0.8,
        "backup:1 should recover ≥ 80% of the ideal epoch time, got {:.1}% \
         ({} vs {})",
        recovery * 100.0,
        backup10.sim_seconds,
        ideal.sim_seconds
    );
    assert!(backup10.dropped_gradients > 0);
    // the straggler's wasted work is attributed to it
    let max_dropper = backup10
        .dropped_by_learner
        .iter()
        .enumerate()
        .max_by_key(|&(_, c)| c)
        .unwrap()
        .0;
    assert_eq!(max_dropper, 0, "{:?}", backup10.dropped_by_learner);
}

/// `hetero none` preserves bit-identical fixed-seed trajectories: a spec
/// that names a factor of exactly 1.0 takes the heterogeneity code path
/// yet must reproduce the quiet run bit for bit (the model's RNG stream
/// is separate from the engine's, and ×1.0 is exact in IEEE 754).
#[test]
fn hetero_none_is_bit_identical_to_unit_factor() {
    let quiet = straggler_cfg(Protocol::NSoftsync { n: 2 }, 4, 6, 3, 240, "none");
    let unit = straggler_cfg(Protocol::NSoftsync { n: 2 }, 4, 6, 3, 240, "slow:0x1");
    let a = run_numeric(&quiet);
    let b = run_numeric(&unit);
    assert_eq!(a.sim_seconds, b.sim_seconds);
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.updates, b.updates);
    assert_eq!(a.theta.unwrap().data, b.theta.unwrap().data);
    // and quiet runs replay themselves exactly
    let c = run_numeric(&quiet);
    assert_eq!(a.sim_seconds, c.sim_seconds);
}

/// Sampled + transient heterogeneity replays bit-identically for a fixed
/// seed: the hetero model draws from its own seeded stream.
#[test]
fn hetero_runs_replay_deterministically() {
    let cfg = straggler_cfg(
        Protocol::NSoftsync { n: 1 },
        4,
        6,
        3,
        240,
        "lognormal:0.5,markov:0.1:0.4:4",
    );
    let a = run_numeric(&cfg);
    let b = run_numeric(&cfg);
    assert_eq!(a.sim_seconds, b.sim_seconds);
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.theta.unwrap().data, b.theta.unwrap().data);
    assert_eq!(a.hetero_factors, b.hetero_factors);
    assert!(
        a.hetero_factors.iter().any(|&f| (f - 1.0).abs() > 1e-9),
        "lognormal sampling actually produced skew: {:?}",
        a.hetero_factors
    );
}

/// The adaptive-n controller walks the splitting parameter toward the
/// target ⟨σ⟩: starting at λ-softsync (n = 8, ⟨σ⟩ ≈ 8) with a target of
/// 2, n must be halved epoch over epoch until the observed staleness
/// lands inside the deadband.
#[test]
fn adaptive_controller_converges_to_target_sigma() {
    let mut cfg = straggler_cfg(Protocol::NSoftsync { n: 8 }, 4, 8, 8, 256, "none");
    cfg.adaptive = AdaptiveSpec::parse("sigma:2").unwrap();
    let r = run_numeric(&cfg);
    assert_eq!(r.epochs.len(), 8, "completed");
    assert!(!r.adaptive.is_empty(), "one decision per epoch");
    let first = r.adaptive.first().unwrap();
    let last = r.adaptive.last().unwrap();
    assert_eq!(first.old_n, 8);
    assert!(
        last.new_n <= 4,
        "n should have walked down toward the target: {:?}",
        r.adaptive.iter().map(|a| a.new_n).collect::<Vec<_>>()
    );
    assert!(last.new_n >= 1);
    assert!(
        last.observed_sigma < first.observed_sigma,
        "⟨σ⟩ must fall as n falls: {} → {}",
        first.observed_sigma,
        last.observed_sigma
    );
    // the decisions carry the epoch timing signal for the log
    assert!(r.adaptive.iter().all(|a| a.epoch_secs > 0.0));
}

/// A kill while the controller sits at the n = λ_active ceiling must
/// retune n down with the quorum, not abort the run: a *static*
/// λ-softsync run dies when λ_active falls below n (the checked quota),
/// but the feedback-controlled run follows the membership down.
#[test]
fn adaptive_n_follows_quorum_down_on_kill() {
    let mut cfg = straggler_cfg(Protocol::NSoftsync { n: 4 }, 4, 4, 4, 256, "none");
    cfg.adaptive = AdaptiveSpec::parse("sigma:10").unwrap();
    cfg.churn = ChurnSchedule::parse("kill:3@0.005").unwrap();
    let r = run_numeric(&cfg);
    assert_eq!(r.epochs.len(), 4, "run survives the kill at the n ceiling");
    assert_eq!(r.final_active_lambda, 3);
    assert!(!r.adaptive.is_empty());
    // the kill (≈5 ms) lands before the first epoch boundary (≈19 ms of
    // virtual time), so the controller's first decision already starts
    // from the clamped n
    assert!(r.adaptive[0].old_n <= 3, "{:?}", r.adaptive);
    assert!(r.adaptive.iter().all(|a| a.new_n <= 3), "{:?}", r.adaptive);
    assert!(r.theta.unwrap().is_finite());
}

/// Per-learner utilization exposes the barrier cost of a straggler: under
/// hardsync with one 10× learner, the fast learners idle (low compute
/// fraction) while the straggler stays near-fully busy.
#[test]
fn utilization_shows_barrier_idling() {
    let r = run_timing(&straggler_cfg(Protocol::Hardsync, 4, 8, 2, 1600, "slow:0x10"));
    assert_eq!(r.learner_utilization.len(), 8);
    let slow = r.learner_utilization[0];
    let fastest = r.learner_utilization[1..]
        .iter()
        .cloned()
        .fold(f64::NEG_INFINITY, f64::max);
    assert!(
        slow > 5.0 * fastest,
        "the straggler computes while the rest wait: slow {slow} vs fast {fastest}"
    );
    assert!(slow > 0.5, "straggler should be busy most of the run: {slow}");
}
