//! Integration tests for the communication subsystem (PR 4): codec
//! bit-identity against the uncompressed baseline, error-feedback
//! losslessness, checkpointed residual/controller state, and the
//! shard-striped Adv\* broadcast — all through the public engine APIs.

use rudra::comm::codec::{CodecSpec, LearnerCodec};
use rudra::comm::stripe::StripePlan;
use rudra::comm::wire::WireModel;
use rudra::coordinator::engine_sim::{run_sim, SimConfig, SimResult};
use rudra::coordinator::learner::MockProvider;
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::netsim::cost::ModelCost;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::straggler::adaptive::AdaptiveSpec;
use rudra::util::prop::check;
use rudra::util::rng::Rng;

const DIM: usize = 6;

fn tiny_model() -> ModelCost {
    ModelCost {
        name: "tiny",
        flops_per_sample: 1.0e6,
        bytes: 1.0e3,
        samples_per_epoch: 96,
    }
}

fn cfg(
    protocol: Protocol,
    arch: Arch,
    lambda: usize,
    shards: usize,
    compress: &str,
) -> SimConfig {
    let mut c = SimConfig::paper(protocol, arch, 4, lambda, 2, tiny_model());
    c.seed = 13;
    c.shards = shards;
    c.compress = CodecSpec::parse(compress).unwrap();
    c
}

fn run_numeric(c: &SimConfig) -> SimResult {
    let mut provider = MockProvider::new(vec![0.25; DIM]);
    run_sim(
        c,
        FlatVec::from_vec(vec![1.0, -2.0, 0.5, 3.0, -1.0, 2.0]),
        Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, DIM),
        LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
        Some(&mut provider),
        None,
    )
    .unwrap()
}

/// Satellite: `compress none` (no codec built) and `topk:1.0` (codec
/// built, everything transmitted, residual permanently drained) must be
/// bit-identical to each other — same virtual time, same event count,
/// same final weights — across all three protocols and S ∈ {1, 4}.
#[test]
fn compress_none_and_topk_full_are_bit_identical() {
    for protocol in [Protocol::Hardsync, Protocol::NSoftsync { n: 1 }, Protocol::Async] {
        for shards in [1usize, 4] {
            let base = run_numeric(&cfg(protocol, Arch::Base, 4, shards, "none"));
            let full = run_numeric(&cfg(protocol, Arch::Base, 4, shards, "topk:1.0"));
            let tag = format!("{} S={shards}", protocol.label());
            assert_eq!(base.sim_seconds, full.sim_seconds, "{tag}: sim time");
            assert_eq!(base.events_processed, full.events_processed, "{tag}: events");
            assert_eq!(base.updates, full.updates, "{tag}: updates");
            assert_eq!(
                base.theta.unwrap().data,
                full.theta.unwrap().data,
                "{tag}: weights must match bit for bit"
            );
            // topk:1.0 never accumulates a residual
            let norms = full.residual_norms;
            assert!(norms.iter().all(|&r| r == 0.0), "{tag}: {norms:?}");
            // and its wire accounting equals the dense sizes
            assert_eq!(base.root_bytes_in, full.root_bytes_in, "{tag}: bytes in");
            assert_eq!(base.root_bytes_out, full.root_bytes_out, "{tag}: bytes out");
        }
    }
}

/// The Adv (leaf-relay) path is also codec-transparent at `topk:1.0`.
#[test]
fn adv_relay_path_bit_identical_at_full_fraction() {
    let base = run_numeric(&cfg(Protocol::NSoftsync { n: 1 }, Arch::Adv, 8, 2, "none"));
    let full = run_numeric(&cfg(Protocol::NSoftsync { n: 1 }, Arch::Adv, 8, 2, "topk:1.0"));
    assert_eq!(base.sim_seconds, full.sim_seconds);
    assert_eq!(base.theta.unwrap().data, full.theta.unwrap().data);
    assert_eq!(base.root_bytes_in, full.root_bytes_in);
}

/// Satellite: error feedback makes top-k lossless in aggregate — over a
/// full accumulation cycle (T gradients plus the ⌈n/k⌉ drain encodes
/// that flush the residual), the transmitted mass equals the input mass
/// per coordinate, and the residual ends exactly empty.
#[test]
fn prop_topk_error_feedback_lossless_over_a_cycle() {
    check(
        "topk_cycle",
        17,
        40,
        |rng| {
            let n = 4 + rng.usize_below(60);
            let frac = 0.05 + rng.f64() * 0.95;
            let steps = 1 + rng.usize_below(12);
            (n, frac, steps, rng.next_u64())
        },
        |&(n, frac, steps, seed)| {
            let mut codec = LearnerCodec::new(CodecSpec::TopK { frac }, n, seed, 0);
            let mut rng = Rng::new(seed);
            let mut in_sum = vec![0.0f64; n];
            let mut out_sum = vec![0.0f64; n];
            for _ in 0..steps {
                let g = FlatVec::from_vec(
                    (0..n).map(|_| rng.range(-1.0, 1.0) as f32).collect(),
                );
                for (s, &x) in in_sum.iter_mut().zip(g.data.iter()) {
                    *s += x as f64;
                }
                let dec = codec.encode(&g).into_dense();
                for (s, &x) in out_sum.iter_mut().zip(dec.data.iter()) {
                    *s += x as f64;
                }
            }
            // drain: zero gradients only move residual mass out; each
            // encode transmits k = ⌈frac·n⌉ entries, so ⌈n/k⌉ suffices
            let k = ((frac * n as f64).ceil() as usize).clamp(1, n);
            let zero = FlatVec::zeros(n);
            for _ in 0..n.div_ceil(k) {
                let dec = codec.encode(&zero).into_dense();
                for (s, &x) in out_sum.iter_mut().zip(dec.data.iter()) {
                    *s += x as f64;
                }
            }
            if codec.residual_norm() != 0.0 {
                return Err(format!(
                    "residual not drained: ‖r‖ = {}",
                    codec.residual_norm()
                ));
            }
            for i in 0..n {
                let err = (in_sum[i] - out_sum[i]).abs();
                // partitions are exact in f32; only the f32 g ⊕ r adds
                // round, so the aggregate agrees to f32 precision
                if err > 1e-4 * (1.0 + in_sum[i].abs()) {
                    return Err(format!(
                        "coordinate {i}: in {} vs out {} (err {err})",
                        in_sum[i], out_sum[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Compressed runs converge on the quadratic bowl (error feedback keeps
/// the descent direction unbiased in aggregate) and book their traffic.
#[test]
fn compressed_numeric_runs_converge_and_account_bytes() {
    for compress in ["topk:0.25", "qsgd:4"] {
        let r = run_numeric(&cfg(Protocol::NSoftsync { n: 1 }, Arch::Base, 4, 2, compress));
        assert!(r.updates > 0, "{compress}");
        let theta = r.theta.unwrap();
        assert!(theta.is_finite(), "{compress}");
        // target is 0.25 everywhere; initial distance ≈ 3.9
        let dist = {
            let mut d = theta.clone();
            d.axpy(-1.0, &FlatVec::from_vec(vec![0.25; DIM]));
            d.norm()
        };
        assert!(dist < 3.5, "{compress}: distance to target {dist}");
        assert_eq!(r.comm_bytes_by_learner.len(), 4, "{compress}");
        assert!(r.comm_bytes_by_learner.iter().all(|&b| b > 0.0), "{compress}");
        assert_eq!(r.residual_norms.len(), 4, "{compress}");
        // compressed ingress is cheaper than the dense run's
        let dense = run_numeric(&cfg(Protocol::NSoftsync { n: 1 }, Arch::Base, 4, 2, "none"));
        assert!(
            r.root_bytes_in < dense.root_bytes_in,
            "{compress}: {} vs {}",
            r.root_bytes_in,
            dense.root_bytes_in
        );
    }
}

/// Checkpoints taken mid-run carry the codec residuals and the adaptive
/// controller (satellite: the controller's retuned n used to be lost).
#[test]
fn checkpoint_carries_comm_and_adaptive_state() {
    let mut c = cfg(Protocol::NSoftsync { n: 4 }, Arch::Base, 8, 2, "qsgd:4");
    c.epochs = 4;
    c.adaptive = AdaptiveSpec::parse("sigma:1,band:0.05").unwrap();
    c.checkpoint_every_updates = 5;
    let mut provider = MockProvider::new(vec![0.25; DIM]);
    let r = run_sim(
        &c,
        FlatVec::from_vec(vec![1.0, -2.0, 0.5, 3.0, -1.0, 2.0]),
        Optimizer::new(OptimizerKind::Sgd, 0.0, DIM),
        LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
        Some(&mut provider),
        None,
    )
    .unwrap();
    assert!(r.checkpoints_taken > 0);
    let restored = r.last_checkpoint.expect("checkpoint captured").restore().unwrap();
    let comm = restored.comm.expect("codec state travels with the checkpoint");
    assert_eq!(comm.residual_norms().len(), 8, "one codec per learner slot");
    let ctl = restored.adaptive.expect("controller travels with the checkpoint");
    match restored.server.protocol() {
        Protocol::NSoftsync { n } => assert_eq!(
            ctl.n(),
            n,
            "restored controller must agree with the restored server's retuned n"
        ),
        other => panic!("unexpected protocol {other:?}"),
    }
    // the controller actually moved off its configured n = 4 by then
    assert!(ctl.n() < 4, "σ-target 1 must have stepped n down, got {}", ctl.n());
}

/// Smoke (CI: comm-smoke job): the acceptance-criteria configuration in
/// miniature — topk:0.01 + shard-striped Adv\* at S = 4 on the Table 1
/// adversarial model moves an order of magnitude fewer root bytes than
/// the flat uncompressed push, and still completes.
#[test]
fn comm_smoke() {
    let mk = |arch: Arch, shards: usize, compress: &str| {
        let mut c = SimConfig::paper(
            Protocol::NSoftsync { n: 1 },
            arch,
            4,
            16,
            1,
            ModelCost::adversarial_300mb(),
        );
        c.seed = 5;
        c.shards = shards;
        c.max_updates = Some(20);
        c.compress = CodecSpec::parse(compress).unwrap();
        run_sim(
            &c,
            FlatVec::zeros(0),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
            LrPolicy::new(Schedule::constant(0.01), Modulation::Auto, 128),
            None,
            None,
        )
        .unwrap()
    };
    let flat = mk(Arch::Base, 4, "none");
    let striped = mk(Arch::AdvStar, 4, "topk:0.01");
    assert!(flat.updates > 0 && striped.updates > 0);
    let per_update =
        |r: &SimResult| (r.root_bytes_in + r.root_bytes_out) / r.updates.max(1) as f64;
    assert!(
        per_update(&striped) * 10.0 <= per_update(&flat),
        "compressed+striped root traffic must be ≥10× below flat dense: {} vs {}",
        per_update(&striped),
        per_update(&flat)
    );
    assert!(
        striped.sim_seconds < flat.sim_seconds,
        "less wire time must mean less simulated time: {} vs {}",
        striped.sim_seconds,
        flat.sim_seconds
    );
}

/// The stripe plan the engines consume: S = 1 reproduces the legacy
/// broadcast period bit for bit; S = 4 divides the payload per hop.
#[test]
fn stripe_plan_consistency_with_wire_model() {
    let cluster = rudra::netsim::cluster::ClusterSpec::p775();
    let m = 300.0e6;
    let flat = StripePlan::new(16, 8, 1).period(&cluster, m);
    let striped = StripePlan::new(16, 8, 4).period(&cluster, m);
    assert!(striped < flat);
    // wire model: pulls stay dense regardless of codec
    let w = WireModel::new(CodecSpec::TopK { frac: 0.01 }, m);
    assert_eq!(w.pull_bytes(), m);
    assert!(w.push_bytes() < m * 0.03);
}
