"""Layer-1 Pallas kernels for the Rudra reproduction.

All kernels are authored TPU-idiomatically (MXU-sized blocks, f32
accumulation, VMEM-resident scratch) but are lowered with
``interpret=True`` so the resulting HLO runs on the CPU PJRT client that
the Rust coordinator embeds (real-TPU lowering emits a Mosaic custom-call
the CPU plugin cannot execute — see DESIGN.md §Hardware-Adaptation).
"""

from .matmul import matmul  # noqa: F401
from .fused_linear import fused_linear  # noqa: F401
from .softmax_xent import softmax_xent, softmax_xent_loss_grad  # noqa: F401
