"""Tiled Pallas matmul — the GEMM hot-spot of a Rudra learner.

The paper (§5.2) notes that "the dominant computation performed by the
learners involves multiple calls to matrix multiplication (GEMM)", and
that small mini-batches proportionally reduce GEMM throughput. This
kernel is that GEMM, written for the TPU MXU:

* grid = (M/bm, N/bn, K/bk) with the K dimension innermost so each
  (i, j) output tile stays resident in a VMEM scratch accumulator across
  the K loop (the classic MXU-feeding schedule);
* blocks default to 128×128×128 — the MXU systolic array is 128×128;
* inputs may be bf16 or f32; accumulation is always f32
  (``preferred_element_type``), matching MXU semantics;
* arbitrary shapes are handled by zero-padding up to block multiples in
  the wrapper and slicing the result back (zero rows/cols contribute
  nothing to the product).

A ``jax.custom_vjp`` makes the kernel differentiable: both cotangent
GEMMs (dx = g·wᵀ, dw = xᵀ·g) are themselves Pallas calls, so the whole
backward pass stays on the kernel path in the exported HLO.

VMEM footprint per grid step = bm·bk + bk·bn input tiles + bm·bn f32
scratch; for the 128³ default that is ≈192 KiB ≪ 16 MiB VMEM, leaving
room for double buffering (see DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    """One (i, j, k) grid step: acc += x_tile @ w_tile."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _ceil_to(value: int, mult: int) -> int:
    return (value + mult - 1) // mult * mult


def _matmul_raw(x, w, bm, bn, bk, out_dtype, interpret):
    """Non-differentiable tiled pallas matmul (see module docstring)."""
    m, k = x.shape
    _, n = w.shape
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp != m or kp != k) else x
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp != k or np_ != n) else w
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
    if mp != m or np_ != n:
        out = out[:m, :n]
    return out


@functools.lru_cache(maxsize=None)
def _make_matmul(bm, bn, bk, out_dtype_name, interpret):
    out_dtype = jnp.dtype(out_dtype_name) if out_dtype_name else None

    @jax.custom_vjp
    def f(x, w):
        od = out_dtype or x.dtype
        return _matmul_raw(x, w, bm, bn, bk, od, interpret)

    def fwd(x, w):
        return f(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        dx = _matmul_raw(g, w.T, bm, bn, bk, x.dtype, interpret)
        dw = _matmul_raw(x.T, g, bm, bn, bk, w.dtype, interpret)
        return dx, dw

    f.defvjp(fwd, bwd)
    return f


def matmul(
    x,
    w,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=None,
    interpret: bool = True,
):
    """Differentiable ``x @ w`` via the tiled Pallas kernel.

    Args:
      x: ``[M, K]`` array (f32 or bf16).
      w: ``[K, N]`` array (same dtype family).
      block_m/n/k: tile sizes; clamped to the (padded) problem size.
      out_dtype: output dtype; defaults to ``x.dtype``.
      interpret: keep True for CPU-PJRT execution (see module docstring).

    Returns:
      ``[M, N]`` product, f32-accumulated.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {x.shape} @ {w.shape}")
    if x.shape[1] != w.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    name = jnp.dtype(out_dtype).name if out_dtype else None
    return _make_matmul(block_m, block_n, block_k, name, interpret)(x, w)


def vmem_bytes(block_m: int, block_n: int, block_k: int, in_bytes: int = 4) -> int:
    """Static VMEM-footprint estimate for one grid step (perf analysis)."""
    return (
        block_m * block_k * in_bytes
        + block_k * block_n * in_bytes
        + block_m * block_n * 4  # f32 scratch accumulator
    )
