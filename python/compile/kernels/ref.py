"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: ``python/tests`` sweeps shapes and
dtypes with hypothesis and asserts the kernels match these to float
tolerance; the L2 models can also be built entirely from these (``use_pallas
=False``) which is how the model-level equivalence tests work.
"""

import jax
import jax.numpy as jnp


def matmul_ref(x, w, out_dtype=None):
    """f32-accumulated ``x @ w``, matching the kernel's MXU semantics."""
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(out_dtype)


def fused_linear_ref(x, w, b, act="none", out_dtype=None):
    out_dtype = out_dtype or x.dtype
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "gelu":
        y = jax.nn.gelu(y)
    elif act == "tanh":
        y = jnp.tanh(y)
    elif act != "none":
        raise ValueError(f"unknown activation {act!r}")
    return y.astype(out_dtype)


def softmax_xent_loss_grad_ref(logits, labels):
    """Per-row cross-entropy loss and logit gradient."""
    z = logits.astype(jnp.float32)
    zmax = jnp.max(z, axis=-1, keepdims=True)
    shifted = z - zmax
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1, keepdims=True))
    logp = shifted - lse
    onehot = jax.nn.one_hot(labels, z.shape[-1], dtype=jnp.float32)
    loss = -jnp.sum(logp * onehot, axis=-1)
    grad = (jnp.exp(logp) - onehot).astype(logits.dtype)
    return loss, grad


def softmax_xent_ref(logits, labels):
    loss, _ = softmax_xent_loss_grad_ref(logits, labels)
    return jnp.mean(loss)
