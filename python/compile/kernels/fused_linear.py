"""Fused linear layer: ``act(x @ w + b)`` as a single Pallas kernel.

Fusing the bias add and activation into the GEMM epilogue saves one full
HBM round-trip of the [M, N] activation tensor — on TPU the tile is still
in VMEM when the epilogue runs. The learner's fully-connected layers (and
the transformer's MLP blocks) use this, so it sits directly on the
per-mini-batch hot path the paper's runtime columns measure.

Differentiability: the forward kernel also emits the pre-activation
tensor, which the ``custom_vjp`` uses to form ``dpre = g · act'(pre)``;
the two cotangent GEMMs then go through the Pallas matmul kernel, keeping
the entire backward pass on the kernel path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .matmul import _ceil_to, _matmul_raw

_ACTS = ("none", "relu", "gelu", "tanh")


def _act_fn(v, act: str):
    if act == "relu":
        return jnp.maximum(v, 0.0)
    if act == "gelu":
        return jax.nn.gelu(v)
    if act == "tanh":
        return jnp.tanh(v)
    return v


def _fused_kernel(x_ref, w_ref, b_ref, o_ref, pre_ref, acc_ref, *, nk: int, act: str):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        pre = acc_ref[...] + b_ref[...].astype(jnp.float32)
        pre_ref[...] = pre.astype(pre_ref.dtype)
        o_ref[...] = _act_fn(pre, act).astype(o_ref.dtype)


def _fused_raw(x, w, b, act, bm, bn, bk, out_dtype, interpret):
    """Returns (y, pre); non-differentiable."""
    m, k = x.shape
    _, n = w.shape
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp != m or kp != k) else x
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp != k or np_ != n) else w
    bp = (jnp.pad(b, (0, np_ - n)) if np_ != n else b).reshape(1, np_)
    nk = kp // bk

    y, pre = pl.pallas_call(
        functools.partial(_fused_kernel, nk=nk, act=act),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), out_dtype),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp, bp)
    if mp != m or np_ != n:
        y, pre = y[:m, :n], pre[:m, :n]
    return y, pre


@functools.lru_cache(maxsize=None)
def _make_fused(act, bm, bn, bk, out_dtype_name, interpret):
    out_dtype = jnp.dtype(out_dtype_name) if out_dtype_name else None

    @jax.custom_vjp
    def f(x, w, b):
        od = out_dtype or x.dtype
        y, _ = _fused_raw(x, w, b, act, bm, bn, bk, od, interpret)
        return y

    def fwd(x, w, b):
        od = out_dtype or x.dtype
        y, pre = _fused_raw(x, w, b, act, bm, bn, bk, od, interpret)
        return y, (x, w, pre)

    def bwd(res, g):
        x, w, pre = res
        if act == "none":
            dpre = g.astype(jnp.float32)
        else:
            _, vjp = jax.vjp(lambda p: _act_fn(p, act), pre)
            (dpre,) = vjp(g.astype(jnp.float32))
        dpre = dpre.astype(x.dtype)
        dx = _matmul_raw(dpre, w.T, bm, bn, bk, x.dtype, interpret)
        dw = _matmul_raw(x.T, dpre, bm, bn, bk, w.dtype, interpret)
        db = jnp.sum(dpre.astype(jnp.float32), axis=0).astype(w.dtype)
        return dx, dw, db

    f.defvjp(fwd, bwd)
    return f


def fused_linear(
    x,
    w,
    b,
    *,
    act: str = "none",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=None,
    interpret: bool = True,
):
    """Differentiable ``act(x @ w + b)`` with the epilogue fused into the GEMM.

    Args:
      x: ``[M, K]``; w: ``[K, N]``; b: ``[N]``.
      act: one of ``none|relu|gelu|tanh``.
    """
    if act not in _ACTS:
        raise ValueError(f"unknown activation {act!r}; expected one of {_ACTS}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape != (n,):
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")
    name = jnp.dtype(out_dtype).name if out_dtype else None
    return _make_fused(act, block_m, block_n, block_k, name, interpret)(x, w, b)
