"""Fused row-softmax + cross-entropy (+ gradient) Pallas kernel.

Computes, for every row of a ``[B, C]`` logit matrix with integer labels:

* ``loss_b  = logsumexp(z_b) - z_b[y_b]``
* ``grad_b  = softmax(z_b) - onehot(y_b)``

in one pass, so the ``[B, C]`` probability tensor never leaves VMEM.
A ``jax.custom_vjp`` wrapper (``softmax_xent``) exposes the mean loss to
``jax.grad`` while reusing the kernel-computed gradient — the backward
pass costs one elementwise scale instead of a second softmax.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _ceil_to


def _xent_kernel(z_ref, y_ref, loss_ref, grad_ref):
    z = z_ref[...].astype(jnp.float32)  # [bb, C]
    y = y_ref[...]  # [bb, 1] int32
    zmax = jnp.max(z, axis=-1, keepdims=True)
    shifted = z - zmax
    ez = jnp.exp(shifted)
    sez = jnp.sum(ez, axis=-1, keepdims=True)
    lse = jnp.log(sez)  # [bb, 1]
    cols = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)
    onehot = (cols == y).astype(jnp.float32)  # [bb, C]
    correct = jnp.sum(shifted * onehot, axis=-1, keepdims=True)
    loss_ref[...] = (lse - correct).astype(loss_ref.dtype)
    grad_ref[...] = (ez / sez - onehot).astype(grad_ref.dtype)


def softmax_xent_loss_grad(logits, labels, *, block_b: int = 128, interpret: bool = True):
    """Per-row ``(loss[B], grad[B, C])`` from the fused kernel.

    Rows are processed in blocks of ``block_b``; the class dimension stays
    whole (C ≤ a few thousand fits VMEM comfortably: 128·4096·4 B = 2 MiB).
    Padded rows get label -1, which matches no column, and their loss rows
    are sliced away.
    """
    b, c = logits.shape
    if labels.shape != (b,):
        raise ValueError(f"labels shape {labels.shape} != ({b},)")
    bb = min(block_b, _ceil_to(b, 8))
    bp = _ceil_to(b, bb)
    zp = jnp.pad(logits, ((0, bp - b), (0, 0))) if bp != b else logits
    yp = labels.astype(jnp.int32)
    if bp != b:
        yp = jnp.pad(yp, (0, bp - b), constant_values=-1)
    yp = yp.reshape(bp, 1)

    loss, grad = pl.pallas_call(
        _xent_kernel,
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, 1), jnp.float32),
            jax.ShapeDtypeStruct((bp, c), logits.dtype),
        ],
        interpret=interpret,
    )(zp, yp)
    return loss[:b, 0], grad[:b]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def softmax_xent(logits, labels, interpret: bool = True):
    """Mean cross-entropy over the batch, differentiable w.r.t. logits."""
    loss, _ = softmax_xent_loss_grad(logits, labels, interpret=interpret)
    return jnp.mean(loss)


def _xent_fwd(logits, labels, interpret):
    loss, grad = softmax_xent_loss_grad(logits, labels, interpret=interpret)
    return jnp.mean(loss), (grad, logits.shape[0])


def _xent_bwd(interpret, res, ct):
    grad, b = res
    return (grad * (ct / b), None)


softmax_xent.defvjp(_xent_fwd, _xent_bwd)
