"""Layer-2 JAX models for the Rudra reproduction.

Two model families, both expressed as pure functions of a **flat f32
parameter vector** ``theta`` so the Rust parameter server can treat
weights, gradients, and optimizer state as opaque dense vectors (exactly
how the paper's PS treats the model: "the size of pull and push messages
is the same as the model size"):

* ``cnn_*``  — the paper's CIFAR10 study model family (conv-pool ×2 →
  FC → softmax), scaled to the synthetic benchmark described in
  DESIGN.md §3.
* ``lm_*``   — a decoder-only transformer byte-LM used by the end-to-end
  example (``examples/transformer_e2e.rs``).

All dense projections route through the Layer-1 Pallas kernels
(``use_pallas=True``); setting ``use_pallas=False`` swaps every kernel for
its pure-jnp oracle, which is how the model-level equivalence tests work.

Exported graphs (see ``aot.py``):
* grad:  (theta[P], x, y) -> (grads[P], loss)
* eval:  (theta[P], x, y) -> (per-example loss, per-example correct)
"""

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fused_linear, matmul, softmax_xent
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Flat parameter packing
# ---------------------------------------------------------------------------


class ParamSpec:
    """Ordered (name, shape) table mapping a flat vector to named tensors."""

    def __init__(self, entries):
        self.entries = [(name, tuple(shape)) for name, shape in entries]
        self.offsets = {}
        off = 0
        for name, shape in self.entries:
            n = int(np.prod(shape)) if shape else 1
            self.offsets[name] = (off, n, shape)
            off += n
        self.total = off

    def unpack(self, theta):
        """Flat ``theta[P]`` -> dict of named, shaped tensors (traceable)."""
        out = {}
        for name, (off, n, shape) in self.offsets.items():
            out[name] = jax.lax.dynamic_slice(theta, (off,), (n,)).reshape(shape)
        return out

    def pack(self, tensors) -> np.ndarray:
        """Dict of named numpy arrays -> flat f32 vector."""
        flat = np.zeros(self.total, dtype=np.float32)
        for name, (off, n, shape) in self.offsets.items():
            arr = np.asarray(tensors[name], dtype=np.float32)
            if arr.shape != shape:
                raise ValueError(f"{name}: got {arr.shape}, want {shape}")
            flat[off : off + n] = arr.reshape(-1)
        return flat

    def manifest(self):
        return {
            "total": self.total,
            "entries": [
                {"name": n, "shape": list(s), "offset": self.offsets[n][0]}
                for n, s in self.entries
            ],
        }


# ---------------------------------------------------------------------------
# CNN (the paper's CIFAR10 study model, scaled to the synthetic benchmark)
# ---------------------------------------------------------------------------

CNN_DEFAULT = {
    "height": 12,
    "width": 12,
    "channels": 3,
    "classes": 10,
    "conv1": 16,
    "conv2": 32,
    "fc": 64,
}


def cnn_spec(cfg=None) -> ParamSpec:
    cfg = {**CNN_DEFAULT, **(cfg or {})}
    h, w = cfg["height"], cfg["width"]
    # two 2x2 max-pools
    fh, fw = h // 4, w // 4
    flat = fh * fw * cfg["conv2"]
    return ParamSpec(
        [
            ("conv1/w", (3, 3, cfg["channels"], cfg["conv1"])),
            ("conv1/b", (cfg["conv1"],)),
            ("conv2/w", (3, 3, cfg["conv1"], cfg["conv2"])),
            ("conv2/b", (cfg["conv2"],)),
            ("fc1/w", (flat, cfg["fc"])),
            ("fc1/b", (cfg["fc"],)),
            ("fc2/w", (cfg["fc"], cfg["classes"])),
            ("fc2/b", (cfg["classes"],)),
        ]
    )


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return jax.nn.relu(y + b)


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _linear(x, w, b, act, use_pallas):
    if use_pallas:
        return fused_linear(x, w, b, act=act)
    return kref.fused_linear_ref(x, w, b, act=act)


def cnn_logits(theta, x, cfg=None, use_pallas=True):
    """``x``: [b, H, W, C] f32 -> logits [b, classes]."""
    cfg = {**CNN_DEFAULT, **(cfg or {})}
    p = cnn_spec(cfg).unpack(theta)
    y = _conv(x, p["conv1/w"], p["conv1/b"])
    y = _maxpool2(y)
    y = _conv(y, p["conv2/w"], p["conv2/b"])
    y = _maxpool2(y)
    y = y.reshape(y.shape[0], -1)
    y = _linear(y, p["fc1/w"], p["fc1/b"], "relu", use_pallas)
    return _linear(y, p["fc2/w"], p["fc2/b"], "none", use_pallas)


def cnn_loss(theta, x, y, cfg=None, use_pallas=True):
    logits = cnn_logits(theta, x, cfg, use_pallas)
    if use_pallas:
        return softmax_xent(logits, y)
    return kref.softmax_xent_ref(logits, y)


def cnn_grad_fn(cfg=None, use_pallas=True):
    """(theta, x, y) -> (grads[P], loss) — the learner's calcGradient."""

    def fn(theta, x, y):
        loss, grads = jax.value_and_grad(
            lambda t: cnn_loss(t, x, y, cfg, use_pallas)
        )(theta)
        return grads, loss

    return fn


def cnn_eval_fn(cfg=None, use_pallas=True):
    """(theta, x, y) -> (per-example loss [b], correct [b] f32)."""

    def fn(theta, x, y):
        logits = cnn_logits(theta, x, cfg, use_pallas)
        loss, _ = kref.softmax_xent_loss_grad_ref(logits, y)
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        correct = (pred == y).astype(jnp.float32)
        return loss, correct

    return fn


def init_cnn(seed: int, cfg=None) -> np.ndarray:
    """He-initialized flat parameter vector (deterministic in ``seed``)."""
    cfg = {**CNN_DEFAULT, **(cfg or {})}
    spec = cnn_spec(cfg)
    rng = np.random.default_rng(seed)
    tensors = {}
    for name, shape in spec.entries:
        if name.endswith("/b"):
            tensors[name] = np.zeros(shape, np.float32)
        else:
            fan_in = int(np.prod(shape[:-1]))
            std = math.sqrt(2.0 / fan_in)
            tensors[name] = rng.normal(0.0, std, size=shape).astype(np.float32)
    return spec.pack(tensors)


# ---------------------------------------------------------------------------
# Transformer byte-LM (end-to-end example)
# ---------------------------------------------------------------------------

LM_DEFAULT = {
    "vocab": 256,
    "d_model": 256,
    "layers": 4,
    "heads": 4,
    "mlp_mult": 4,
    "seq": 128,
}


def lm_spec(cfg=None) -> ParamSpec:
    cfg = {**LM_DEFAULT, **(cfg or {})}
    d, v, m = cfg["d_model"], cfg["vocab"], cfg["mlp_mult"]
    entries = [("embed", (v, d)), ("pos", (cfg["seq"], d))]
    for i in range(cfg["layers"]):
        pre = f"layer{i}/"
        entries += [
            (pre + "ln1/g", (d,)),
            (pre + "ln1/b", (d,)),
            (pre + "attn/wqkv", (d, 3 * d)),
            (pre + "attn/bqkv", (3 * d,)),
            (pre + "attn/wo", (d, d)),
            (pre + "attn/bo", (d,)),
            (pre + "ln2/g", (d,)),
            (pre + "ln2/b", (d,)),
            (pre + "mlp/w1", (d, m * d)),
            (pre + "mlp/b1", (m * d,)),
            (pre + "mlp/w2", (m * d, d)),
            (pre + "mlp/b2", (d,)),
        ]
    entries += [("lnf/g", (d,)), ("lnf/b", (d,)), ("head/w", (d, v)), ("head/b", (v,))]
    return ParamSpec(entries)


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _mm(x, w, use_pallas):
    if use_pallas:
        return matmul(x, w)
    return kref.matmul_ref(x, w)


def lm_logits(theta, tokens, cfg=None, use_pallas=True):
    """``tokens``: [b, S] int32 -> logits [b, S, V]."""
    cfg = {**LM_DEFAULT, **(cfg or {})}
    d, nh = cfg["d_model"], cfg["heads"]
    b, s = tokens.shape
    p = lm_spec(cfg).unpack(theta)
    x = p["embed"][tokens] + p["pos"][None, :s, :]
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    neg = jnp.asarray(-1e9, jnp.float32)
    for i in range(cfg["layers"]):
        pre = f"layer{i}/"
        h = _layernorm(x, p[pre + "ln1/g"], p[pre + "ln1/b"])
        qkv = (
            _mm(h.reshape(b * s, d), p[pre + "attn/wqkv"], use_pallas)
            + p[pre + "attn/bqkv"]
        ).reshape(b, s, 3, nh, d // nh)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(d // nh)
        att = jnp.where(mask[None, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b * s, d)
        o = _mm(o, p[pre + "attn/wo"], use_pallas) + p[pre + "attn/bo"]
        x = x + o.reshape(b, s, d)
        h = _layernorm(x, p[pre + "ln2/g"], p[pre + "ln2/b"])
        if use_pallas:
            h1 = fused_linear(
                h.reshape(b * s, d), p[pre + "mlp/w1"], p[pre + "mlp/b1"], act="gelu"
            )
        else:
            h1 = kref.fused_linear_ref(
                h.reshape(b * s, d), p[pre + "mlp/w1"], p[pre + "mlp/b1"], act="gelu"
            )
        h2 = _mm(h1, p[pre + "mlp/w2"], use_pallas) + p[pre + "mlp/b2"]
        x = x + h2.reshape(b, s, d)
    x = _layernorm(x, p["lnf/g"], p["lnf/b"])
    logits = _mm(x.reshape(b * s, d), p["head/w"], use_pallas) + p["head/b"]
    return logits.reshape(b, s, cfg["vocab"])


def lm_loss(theta, tokens, targets, cfg=None, use_pallas=True):
    cfg = {**LM_DEFAULT, **(cfg or {})}
    logits = lm_logits(theta, tokens, cfg, use_pallas)
    flat = logits.reshape(-1, cfg["vocab"])
    y = targets.reshape(-1)
    if use_pallas:
        return softmax_xent(flat, y)
    return kref.softmax_xent_ref(flat, y)


def lm_grad_fn(cfg=None, use_pallas=True):
    def fn(theta, tokens, targets):
        loss, grads = jax.value_and_grad(
            lambda t: lm_loss(t, tokens, targets, cfg, use_pallas)
        )(theta)
        return grads, loss

    return fn


def lm_eval_fn(cfg=None, use_pallas=True):
    """(theta, tok, tgt) -> (per-token loss [b*S], correct [b*S])."""

    def fn(theta, tokens, targets):
        cfg_ = {**LM_DEFAULT, **(cfg or {})}
        logits = lm_logits(theta, tokens, cfg_, use_pallas).reshape(
            -1, cfg_["vocab"]
        )
        y = targets.reshape(-1)
        loss, _ = kref.softmax_xent_loss_grad_ref(logits, y)
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return loss, (pred == y).astype(jnp.float32)

    return fn


def init_lm(seed: int, cfg=None) -> np.ndarray:
    cfg = {**LM_DEFAULT, **(cfg or {})}
    spec = lm_spec(cfg)
    rng = np.random.default_rng(seed)
    n_layers = cfg["layers"]
    tensors = {}
    for name, shape in spec.entries:
        if name.endswith("/g"):
            tensors[name] = np.ones(shape, np.float32)
        elif name.endswith("/b") or name.endswith("/b1") or name.endswith("/b2") or name.endswith("bqkv") or name.endswith("bo"):
            tensors[name] = np.zeros(shape, np.float32)
        elif name in ("embed", "pos"):
            tensors[name] = rng.normal(0.0, 0.02, size=shape).astype(np.float32)
        else:
            fan_in = shape[0]
            std = 0.02
            if name.endswith("wo") or name.endswith("w2"):
                # residual-branch projections scaled down with depth
                std = 0.02 / math.sqrt(2 * n_layers)
            tensors[name] = rng.normal(0.0, std, size=shape).astype(np.float32)
            del fan_in
    return spec.pack(tensors)
