"""Synthetic benchmark data for the Rudra reproduction.

The paper trains on CIFAR10 and ImageNet; neither is available offline
here (repro band 0), so per the substitution rule we generate a synthetic
benchmark that exercises the identical code path and preserves the
optimizer-dynamics phenomena under study (staleness sensitivity, μλ
generalization trends — see DESIGN.md §3):

* **Images** — a fixed random *teacher* CNN labels smoothed Gaussian
  images; Gumbel noise at temperature ``label_temp`` injects an
  irreducible error floor. Class boundaries are non-linear, so the
  student CNN has to genuinely learn.
* **Text** — a template/Zipf sentence generator produces a byte corpus
  for the transformer end-to-end example.

Binary formats (shared with ``rust/src/data/loader.rs``, little-endian):

* images:  ``RUDRAIMG`` u32 ver, u32 n, u32 h, u32 w, u32 c, u32 classes,
  f32 images [n·h·w·c], i32 labels [n]
* corpus:  ``RUDRATXT`` u32 ver, u64 len, bytes
* weights: ``RUDRAWTS`` u32 ver, u64 p, f32 [p]
"""

import struct

import numpy as np

IMG_MAGIC = b"RUDRAIMG"
TXT_MAGIC = b"RUDRATXT"
WTS_MAGIC = b"RUDRAWTS"


def _smooth(imgs: np.ndarray) -> np.ndarray:
    """3x3 box filter per channel — gives images spatial structure."""
    out = np.copy(imgs)
    acc = np.zeros_like(imgs)
    cnt = np.zeros_like(imgs)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            shifted = np.roll(np.roll(out, dy, axis=1), dx, axis=2)
            acc += shifted
            cnt += 1
    return acc / cnt


def _teacher_logits(x: np.ndarray, rng: np.random.Generator, classes: int):
    """A fixed 2-layer random conv 'teacher' network, evaluated in numpy."""
    n, h, w, c = x.shape
    k1 = rng.normal(0, 1.2 / np.sqrt(9 * c), size=(3, 3, c, 12)).astype(np.float32)
    k2 = rng.normal(0, 1.2 / np.sqrt(12), size=(12, classes)).astype(np.float32)

    # 'SAME' 3x3 conv via shifts
    y = np.zeros((n, h, w, 12), np.float32)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            shifted = np.roll(np.roll(x, dy, axis=1), dx, axis=2)
            y += shifted @ k1[dy + 1, dx + 1]
    y = np.maximum(y, 0.0)
    pooled = y.mean(axis=(1, 2))  # [n, 12]
    return pooled @ k2  # [n, classes]


def gen_images(
    n: int,
    h: int = 12,
    w: int = 12,
    c: int = 3,
    classes: int = 10,
    seed: int = 0,
    label_temp: float = 0.1,
):
    """Returns (images [n,h,w,c] f32, labels [n] i32)."""
    rng = np.random.default_rng(seed)
    teacher_rng = np.random.default_rng(987654321)  # teacher fixed across splits
    x = rng.normal(0, 1, size=(n, h, w, c)).astype(np.float32)
    x = _smooth(x)
    x -= x.mean()
    x /= x.std() + 1e-8
    logits = _teacher_logits(x, teacher_rng, classes)
    # Column-normalize so no class dominates the argmax (keeps the label
    # marginal near-uniform; an untrained student then sits near 90%
    # error on 10 classes, matching the paper's CIFAR10 starting point),
    # then row-normalize for a consistent temperature scale.
    logits = (logits - logits.mean(axis=0, keepdims=True)) / (
        logits.std(axis=0, keepdims=True) + 1e-8
    )
    logits = (logits - logits.mean(axis=1, keepdims=True)) / (
        logits.std(axis=1, keepdims=True) + 1e-8
    )
    gumbel = rng.gumbel(size=logits.shape).astype(np.float32)
    labels = np.argmax(logits / max(label_temp, 1e-6) + gumbel, axis=1).astype(
        np.int32
    )
    return x, labels


def write_images(path: str, images: np.ndarray, labels: np.ndarray, classes: int):
    n, h, w, c = images.shape
    with open(path, "wb") as f:
        f.write(IMG_MAGIC)
        f.write(struct.pack("<IIIIII", 1, n, h, w, c, classes))
        f.write(images.astype("<f4").tobytes())
        f.write(labels.astype("<i4").tobytes())


def read_images(path: str):
    with open(path, "rb") as f:
        magic = f.read(8)
        assert magic == IMG_MAGIC, magic
        ver, n, h, w, c, classes = struct.unpack("<IIIIII", f.read(24))
        assert ver == 1
        images = np.frombuffer(f.read(n * h * w * c * 4), "<f4").reshape(n, h, w, c)
        labels = np.frombuffer(f.read(n * 4), "<i4")
    return images, labels, classes


_SUBJECTS = ["the learner", "a server", "the gradient", "one replica", "the model",
             "a worker", "the scheduler", "the optimizer", "the batch", "a shard"]
_VERBS = ["pushes", "pulls", "averages", "updates", "computes", "broadcasts",
          "synchronizes", "delays", "samples", "aggregates"]
_OBJECTS = ["the weights", "a minibatch", "stale gradients", "the timestamp",
            "the parameters", "a vector clock", "the staleness", "the epoch",
            "its replica", "the momentum"]
_TAILS = ["quickly", "asynchronously", "with staleness two", "before the epoch ends",
          "under hardsync", "under softsync", "at the parameter server",
          "without blocking", "in bounded time", "after the pull"]


def gen_corpus(n_bytes: int = 262144, seed: int = 7) -> bytes:
    """Zipf-weighted template sentences — structured, compressible text."""
    rng = np.random.default_rng(seed)

    def pick(options):
        # Zipfian rank weighting keeps n-gram statistics learnable
        ranks = np.arange(1, len(options) + 1, dtype=np.float64)
        probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        return options[rng.choice(len(options), p=probs)]

    parts = []
    total = 0
    while total < n_bytes:
        s = f"{pick(_SUBJECTS)} {pick(_VERBS)} {pick(_OBJECTS)} {pick(_TAILS)}. "
        parts.append(s)
        total += len(s)
    return ("".join(parts)[:n_bytes]).encode("ascii")


def write_corpus(path: str, data: bytes):
    with open(path, "wb") as f:
        f.write(TXT_MAGIC)
        f.write(struct.pack("<IQ", 1, len(data)))
        f.write(data)


def write_weights(path: str, theta: np.ndarray):
    theta = np.asarray(theta, dtype="<f4").reshape(-1)
    with open(path, "wb") as f:
        f.write(WTS_MAGIC)
        f.write(struct.pack("<IQ", 1, theta.size))
        f.write(theta.tobytes())


def read_weights(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        magic = f.read(8)
        assert magic == WTS_MAGIC, magic
        ver, p = struct.unpack("<IQ", f.read(12))
        assert ver == 1
        return np.frombuffer(f.read(p * 4), "<f4")
