"""L1 perf analysis: block-shape sweep for the Pallas kernels.

interpret=True gives CPU-numpy timings only — NOT a TPU proxy — so this
tool optimizes *structure*: for each candidate block shape it reports

* VMEM footprint per grid step (input tiles + f32 accumulator), which
  must leave headroom for double buffering inside the 16 MiB budget;
* an MXU-utilization estimate: the fraction of each (bm, bk)·(bk, bn)
  tile-multiply that lands on full 128×128×128 systolic passes, i.e.
  (bm·bn·bk) / (⌈bm/128⌉·⌈bn/128⌉·⌈bk/128⌉·128³) — padding waste;
* grid-step count (smaller = less per-step launch/pipeline overhead);
* wall time under interpret mode relative to the pure-jnp oracle, as a
  *correctness-path* sanity number only.

Usage: cd python && python -m compile.perf_kernels
"""

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import matmul
from .kernels import ref as kref
from .kernels.matmul import vmem_bytes

VMEM_BUDGET = 16 * 1024 * 1024

# The model's dominant GEMMs: (label, M, K, N)
WORKLOADS = [
    ("cnn fc1 μ=128", 128, 288, 64),
    ("lm qkv b*s=1024", 1024, 256, 768),
    ("lm mlp1", 1024, 256, 1024),
    ("lm head", 1024, 256, 256),
]

BLOCKS = [(64, 64, 64), (128, 128, 128), (256, 128, 128), (128, 128, 256), (256, 256, 128)]


def mxu_utilization(m, k, n, bm, bk, bn):
    """Fraction of issued MXU work that is useful (non-padding)."""
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    tiles = math.ceil(m / bm) * math.ceil(k / bk) * math.ceil(n / bn)
    issued = tiles * (
        math.ceil(bm / 128) * math.ceil(bk / 128) * math.ceil(bn / 128) * 128**3
    )
    return (m * k * n) / issued


def grid_steps(m, k, n, bm, bk, bn):
    return math.ceil(m / bm) * math.ceil(n / bn) * math.ceil(k / bk)


def time_fn(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def main():
    print("L1 block-shape sweep (structure metrics; interpret timings are CPU-only)\n")
    rng = np.random.default_rng(0)
    for label, m, k, n in WORKLOADS:
        x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        ref_t = time_fn(jax.jit(kref.matmul_ref), x, w)
        print(f"== {label}: [{m}x{k}]·[{k}x{n}]  (jnp ref {ref_t*1e3:.2f} ms)")
        print(f"   {'blocks':>16} {'VMEM/step':>10} {'dbl-buf ok':>10} {'MXU util':>9} {'steps':>6} {'interp ms':>10}")
        best = None
        for bm, bn, bk in BLOCKS:
            vm = vmem_bytes(min(bm, m), min(bn, n), min(bk, k))
            util = mxu_utilization(m, k, n, bm, bk, bn)
            steps = grid_steps(m, k, n, bm, bk, bn)
            f = jax.jit(
                lambda a, b, bm=bm, bn=bn, bk=bk: matmul(
                    a, b, block_m=bm, block_n=bn, block_k=bk
                )
            )
            t = time_fn(f, x, w)
            ok = "yes" if 2 * vm < VMEM_BUDGET else "NO"
            print(
                f"   {f'{bm}x{bn}x{bk}':>16} {vm/1024:>8.0f}KB {ok:>10} {util:>8.1%} {steps:>6} {t*1e3:>9.2f}"
            )
            score = (util, -steps)
            if best is None or score > best[0]:
                best = (score, (bm, bn, bk))
        print(f"   -> structure pick: {best[1]}\n")


if __name__ == "__main__":
    main()
