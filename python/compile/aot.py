"""AOT compile path: lower every L2 graph to HLO **text** + write data.

Run once by ``make artifacts``; Python never appears on the training hot
path. The Rust runtime loads these with ``HloModuleProto::from_text_file``.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs (under ``artifacts/``):
* ``cnn_grad_b{μ}.hlo.txt``  for μ ∈ {4, 8, 16, 32, 64, 128} — the
  learner's calcGradient graph (theta, x, y) -> (grads, loss)
* ``cnn_eval_b{B}.hlo.txt``  — (theta, x, y) -> (loss[b], correct[b])
* ``lm_grad_b{μ}.hlo.txt`` / ``lm_eval_b{μ}.hlo.txt`` — transformer LM
* ``cnn_init.bin`` / ``lm_init.bin`` — deterministic initial weights
* ``data/synth_train.bin`` / ``data/synth_test.bin`` / ``corpus.bin``
* ``manifest.json`` — the index the Rust side reads
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen, model

CNN_BATCHES = [4, 8, 16, 32, 64, 128]
EVAL_BATCH = 128
LM_BATCH = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, specs, path: str) -> int:
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def cnn_flops_per_sample(cfg) -> int:
    """Analytic forward FLOPs of the study CNN (multiply+add = 2 FLOPs)."""
    h, w, c = cfg["height"], cfg["width"], cfg["channels"]
    f = 0
    f += 2 * h * w * 9 * c * cfg["conv1"]  # conv1 (SAME)
    h2, w2 = h // 2, w // 2
    f += 2 * h2 * w2 * 9 * cfg["conv1"] * cfg["conv2"]  # conv2
    h4, w4 = h // 4, w // 4
    flat = h4 * w4 * cfg["conv2"]
    f += 2 * flat * cfg["fc"] + 2 * cfg["fc"] * cfg["classes"]
    return f


def lm_flops_per_token(cfg) -> int:
    d, L, m, v = cfg["d_model"], cfg["layers"], cfg["mlp_mult"], cfg["vocab"]
    s = cfg["seq"]
    per_layer = 2 * (4 * d * d + 2 * m * d * d) + 2 * 2 * s * d  # proj + attn
    return L * per_layer + 2 * d * v


def build(out_dir: str, args) -> dict:
    os.makedirs(os.path.join(out_dir, "data"), exist_ok=True)
    cnn_cfg = dict(model.CNN_DEFAULT)
    lm_cfg = {**model.LM_DEFAULT, "seq": args.lm_seq, "d_model": args.lm_dmodel,
              "layers": args.lm_layers}

    manifest = {"version": 1}

    # ----- datasets ------------------------------------------------------
    h, w, c, nc = (cnn_cfg["height"], cnn_cfg["width"], cnn_cfg["channels"],
                   cnn_cfg["classes"])
    train_x, train_y = datagen.gen_images(args.train_n, h, w, c, nc, seed=11)
    test_x, test_y = datagen.gen_images(args.test_n, h, w, c, nc, seed=22)
    datagen.write_images(os.path.join(out_dir, "data/synth_train.bin"),
                         train_x, train_y, nc)
    datagen.write_images(os.path.join(out_dir, "data/synth_test.bin"),
                         test_x, test_y, nc)
    corpus = datagen.gen_corpus(args.corpus_bytes, seed=7)
    datagen.write_corpus(os.path.join(out_dir, "data/corpus.bin"), corpus)
    manifest["data"] = {
        "train": "data/synth_train.bin",
        "test": "data/synth_test.bin",
        "corpus": "data/corpus.bin",
        "train_n": args.train_n,
        "test_n": args.test_n,
        "height": h, "width": w, "channels": c, "classes": nc,
        "corpus_bytes": len(corpus),
    }

    # ----- CNN ------------------------------------------------------------
    spec = model.cnn_spec(cnn_cfg)
    theta0 = model.init_cnn(seed=1234, cfg=cnn_cfg)
    datagen.write_weights(os.path.join(out_dir, "cnn_init.bin"), theta0)
    tspec = jax.ShapeDtypeStruct((spec.total,), jnp.float32)
    grad_paths = {}
    for mu in CNN_BATCHES:
        xspec = jax.ShapeDtypeStruct((mu, h, w, c), jnp.float32)
        yspec = jax.ShapeDtypeStruct((mu,), jnp.int32)
        name = f"cnn_grad_b{mu}.hlo.txt"
        n = lower_to_file(model.cnn_grad_fn(cnn_cfg, use_pallas=True),
                          (tspec, xspec, yspec), os.path.join(out_dir, name))
        print(f"  {name}: {n} chars")
        grad_paths[str(mu)] = name
    xspec = jax.ShapeDtypeStruct((EVAL_BATCH, h, w, c), jnp.float32)
    yspec = jax.ShapeDtypeStruct((EVAL_BATCH,), jnp.int32)
    eval_name = f"cnn_eval_b{EVAL_BATCH}.hlo.txt"
    lower_to_file(model.cnn_eval_fn(cnn_cfg, use_pallas=True),
                  (tspec, xspec, yspec), os.path.join(out_dir, eval_name))
    manifest["cnn"] = {
        "params": spec.total,
        "cfg": cnn_cfg,
        "batches": CNN_BATCHES,
        "grad": grad_paths,
        "eval": {"batch": EVAL_BATCH, "path": eval_name},
        "init": "cnn_init.bin",
        "flops_per_sample": cnn_flops_per_sample(cnn_cfg),
        "spec": spec.manifest(),
    }

    # ----- transformer LM --------------------------------------------------
    if not args.skip_lm:
        lspec = model.lm_spec(lm_cfg)
        ltheta0 = model.init_lm(seed=4321, cfg=lm_cfg)
        datagen.write_weights(os.path.join(out_dir, "lm_init.bin"), ltheta0)
        tspec = jax.ShapeDtypeStruct((lspec.total,), jnp.float32)
        tok = jax.ShapeDtypeStruct((LM_BATCH, lm_cfg["seq"]), jnp.int32)
        grad_name = f"lm_grad_b{LM_BATCH}.hlo.txt"
        n = lower_to_file(model.lm_grad_fn(lm_cfg, use_pallas=True),
                          (tspec, tok, tok), os.path.join(out_dir, grad_name))
        print(f"  {grad_name}: {n} chars")
        eval_name = f"lm_eval_b{LM_BATCH}.hlo.txt"
        lower_to_file(model.lm_eval_fn(lm_cfg, use_pallas=True),
                      (tspec, tok, tok), os.path.join(out_dir, eval_name))
        manifest["lm"] = {
            "params": lspec.total,
            "cfg": lm_cfg,
            "batch": LM_BATCH,
            "grad": grad_name,
            "eval": eval_name,
            "init": "lm_init.bin",
            "flops_per_token": lm_flops_per_token(lm_cfg),
        }

    return manifest


def config_digest(args) -> str:
    keys = sorted(vars(args).items())
    src_dir = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256(repr(keys).encode())
    for fn in sorted(os.listdir(src_dir)) + sorted(
        os.listdir(os.path.join(src_dir, "kernels"))
    ):
        path = os.path.join(src_dir, fn)
        if not os.path.isfile(path):
            path = os.path.join(src_dir, "kernels", fn)
        if path.endswith(".py") and os.path.isfile(path):
            h.update(open(path, "rb").read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; artifacts land in its directory")
    ap.add_argument("--train-n", type=int, default=8192)
    ap.add_argument("--test-n", type=int, default=1024)
    ap.add_argument("--corpus-bytes", type=int, default=262144)
    ap.add_argument("--lm-seq", type=int, default=128)
    ap.add_argument("--lm-dmodel", type=int, default=256)
    ap.add_argument("--lm-layers", type=int, default=4)
    ap.add_argument("--skip-lm", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    digest = config_digest(args)
    if not args.force and os.path.exists(args.out):
        try:
            old = json.load(open(args.out))
            if old.get("digest") == digest:
                print(f"artifacts up to date ({args.out}); use --force to rebuild")
                return
        except Exception:
            pass

    manifest = build(out_dir, args)
    manifest["digest"] = digest
    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
