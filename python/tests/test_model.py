"""Model-level tests: shapes, packing, pallas↔ref equivalence, learning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def rand_batch(rng, b, cfg):
    x = rng.standard_normal((b, cfg["height"], cfg["width"], cfg["channels"]))
    y = rng.integers(0, cfg["classes"], size=b)
    return jnp.asarray(x.astype(np.float32)), jnp.asarray(y.astype(np.int32))


class TestParamSpec:
    def test_pack_unpack_roundtrip(self):
        spec = model.ParamSpec([("a", (2, 3)), ("b", (4,)), ("c", (1, 1, 2))])
        assert spec.total == 12
        rng = np.random.default_rng(0)
        tensors = {n: rng.standard_normal(s).astype(np.float32) for n, s in spec.entries}
        flat = spec.pack(tensors)
        out = spec.unpack(jnp.asarray(flat))
        for n, s in spec.entries:
            np.testing.assert_array_equal(np.asarray(out[n]), tensors[n])

    def test_pack_rejects_wrong_shape(self):
        spec = model.ParamSpec([("a", (2, 2))])
        with pytest.raises(ValueError):
            spec.pack({"a": np.zeros((3, 2), np.float32)})

    def test_manifest_offsets_are_contiguous(self):
        spec = model.cnn_spec()
        man = spec.manifest()
        off = 0
        for e in man["entries"]:
            assert e["offset"] == off
            off += int(np.prod(e["shape"]))
        assert off == man["total"] == spec.total


class TestCnn:
    def test_param_count(self):
        # conv1 3·3·3·16+16, conv2 3·3·16·32+32, fc1 288·64+64, fc2 64·10+10
        assert model.cnn_spec().total == 432 + 16 + 4608 + 32 + 18432 + 64 + 640 + 10

    def test_logits_shape(self):
        theta = jnp.asarray(model.init_cnn(0))
        rng = np.random.default_rng(1)
        x, _ = rand_batch(rng, 8, model.CNN_DEFAULT)
        assert model.cnn_logits(theta, x).shape == (8, 10)

    def test_grad_pallas_equals_ref(self):
        theta = jnp.asarray(model.init_cnn(0))
        rng = np.random.default_rng(2)
        x, y = rand_batch(rng, 8, model.CNN_DEFAULT)
        g1, l1 = jax.jit(model.cnn_grad_fn(use_pallas=True))(theta, x, y)
        g2, l2 = jax.jit(model.cnn_grad_fn(use_pallas=False))(theta, x, y)
        np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)

    def test_grad_matches_finite_difference(self):
        cfg = {**model.CNN_DEFAULT, "height": 8, "width": 8, "conv1": 4, "conv2": 4, "fc": 8}
        theta = jnp.asarray(model.init_cnn(3, cfg))
        rng = np.random.default_rng(3)
        x, y = rand_batch(rng, 4, cfg)
        grads, _ = jax.jit(model.cnn_grad_fn(cfg, use_pallas=True))(theta, x, y)
        # probe a few random coordinates
        eps = 1e-3
        loss = lambda t: float(model.cnn_loss(t, x, y, cfg, use_pallas=False))
        idx = rng.integers(0, theta.shape[0], size=5)
        for i in idx:
            e = jnp.zeros_like(theta).at[i].set(eps)
            fd = (loss(theta + e) - loss(theta - e)) / (2 * eps)
            assert abs(fd - float(grads[i])) < 5e-2, f"coord {i}: fd={fd} ad={grads[i]}"

    def test_sgd_reduces_loss(self):
        theta = jnp.asarray(model.init_cnn(4))
        rng = np.random.default_rng(4)
        x, y = rand_batch(rng, 32, model.CNN_DEFAULT)
        grad_fn = jax.jit(model.cnn_grad_fn(use_pallas=True))
        losses = []
        for _ in range(20):
            g, l = grad_fn(theta, x, y)
            losses.append(float(l))
            theta = theta - 0.05 * g
        assert losses[-1] < losses[0] * 0.8, losses

    def test_eval_fn_counts_correct(self):
        theta = jnp.asarray(model.init_cnn(5))
        rng = np.random.default_rng(5)
        x, y = rand_batch(rng, 16, model.CNN_DEFAULT)
        loss, correct = jax.jit(model.cnn_eval_fn(use_pallas=True))(theta, x, y)
        assert loss.shape == (16,)
        assert correct.shape == (16,)
        assert set(np.unique(np.asarray(correct))) <= {0.0, 1.0}

    def test_init_deterministic(self):
        np.testing.assert_array_equal(model.init_cnn(7), model.init_cnn(7))
        assert not np.array_equal(model.init_cnn(7), model.init_cnn(8))


class TestLm:
    CFG = {**model.LM_DEFAULT, "d_model": 64, "layers": 2, "heads": 2, "seq": 32}

    def test_logits_shape_and_causality(self):
        theta = jnp.asarray(model.init_lm(0, self.CFG))
        rng = np.random.default_rng(0)
        tok = jnp.asarray(rng.integers(0, 256, size=(2, 32)).astype(np.int32))
        logits = model.lm_logits(theta, tok, self.CFG, use_pallas=False)
        assert logits.shape == (2, 32, 256)
        # causality: changing a later token must not affect earlier logits
        tok2 = tok.at[:, 20].set((tok[:, 20] + 1) % 256)
        logits2 = model.lm_logits(theta, tok2, self.CFG, use_pallas=False)
        np.testing.assert_allclose(
            logits[:, :20], logits2[:, :20], rtol=1e-4, atol=1e-4
        )
        assert not np.allclose(logits[:, 20:], logits2[:, 20:], atol=1e-4)

    def test_grad_pallas_equals_ref(self):
        theta = jnp.asarray(model.init_lm(1, self.CFG))
        rng = np.random.default_rng(1)
        tok = jnp.asarray(rng.integers(0, 256, size=(2, 32)).astype(np.int32))
        g1, l1 = jax.jit(model.lm_grad_fn(self.CFG, use_pallas=True))(theta, tok, tok)
        g2, l2 = jax.jit(model.lm_grad_fn(self.CFG, use_pallas=False))(theta, tok, tok)
        np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(g1, g2, rtol=1e-3, atol=1e-4)

    def test_initial_loss_near_uniform(self):
        theta = jnp.asarray(model.init_lm(2, self.CFG))
        rng = np.random.default_rng(2)
        tok = jnp.asarray(rng.integers(0, 256, size=(2, 32)).astype(np.int32))
        loss = model.lm_loss(theta, tok, tok, self.CFG, use_pallas=False)
        assert abs(float(loss) - np.log(256)) < 0.5

    def test_sgd_learns_repetition(self):
        # A repeating corpus is easy; loss should fall fast.
        theta = jnp.asarray(model.init_lm(3, self.CFG))
        pattern = np.tile(np.arange(16, dtype=np.int32), 4)[None, :32]
        tok = jnp.asarray(np.repeat(pattern, 2, axis=0))
        tgt = jnp.asarray(np.roll(np.asarray(tok), -1, axis=1))
        grad_fn = jax.jit(model.lm_grad_fn(self.CFG, use_pallas=True))
        first = None
        for _ in range(15):
            g, l = grad_fn(theta, tok, tgt)
            first = first if first is not None else float(l)
            theta = theta - 0.5 * g
        assert float(l) < first * 0.7, (first, float(l))
