"""Synthetic data generator tests: determinism, formats, learnability."""

import os

import numpy as np
import pytest

from compile import datagen


class TestImages:
    def test_shapes_and_determinism(self):
        x1, y1 = datagen.gen_images(64, seed=5)
        x2, y2 = datagen.gen_images(64, seed=5)
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)
        assert x1.shape == (64, 12, 12, 3)
        assert y1.shape == (64,)
        x3, _ = datagen.gen_images(64, seed=6)
        assert not np.array_equal(x1, x3)

    def test_labels_in_range_and_spread(self):
        _, y = datagen.gen_images(2000, classes=10, seed=1)
        assert y.min() >= 0 and y.max() < 10
        counts = np.bincount(y, minlength=10)
        # column-normalized teacher logits keep the marginal near-uniform
        # (an untrained student must sit near 90% error on 10 classes)
        assert counts.min() > 80, counts
        assert counts.max() < 450, counts

    def test_normalized_pixels(self):
        x, _ = datagen.gen_images(256, seed=2)
        assert abs(float(x.mean())) < 0.05
        assert abs(float(x.std()) - 1.0) < 0.1

    def test_teacher_fixed_across_splits(self):
        # different sample seeds share the teacher: a classifier trained on
        # split A should transfer to split B, which requires consistent
        # labeling. Proxy check: nearest-neighbour label agreement above
        # chance across splits.
        xa, ya = datagen.gen_images(400, seed=11, label_temp=0.05)
        xb, yb = datagen.gen_images(200, seed=22, label_temp=0.05)
        fa = xa.reshape(len(xa), -1)
        fb = xb.reshape(len(xb), -1)
        # 1-NN from B into A
        agree = 0
        for i in range(len(fb)):
            d = ((fa - fb[i]) ** 2).sum(axis=1)
            agree += int(ya[np.argmin(d)] == yb[i])
        assert agree / len(fb) > 0.15, "cross-split label structure missing"

    def test_roundtrip_file(self, tmp_path):
        x, y = datagen.gen_images(32, seed=3)
        path = os.path.join(tmp_path, "imgs.bin")
        datagen.write_images(path, x, y, 10)
        x2, y2, classes = datagen.read_images(path)
        assert classes == 10
        np.testing.assert_allclose(x, x2, rtol=1e-6)
        np.testing.assert_array_equal(y, y2)


class TestCorpus:
    def test_size_and_determinism(self):
        c1 = datagen.gen_corpus(10_000, seed=7)
        c2 = datagen.gen_corpus(10_000, seed=7)
        assert c1 == c2
        assert len(c1) == 10_000
        assert c1.decode("ascii")  # pure ASCII

    def test_structured_text(self):
        c = datagen.gen_corpus(50_000, seed=1).decode("ascii")
        # template grammar: sentences end with '. '
        assert c.count(". ") > 200
        assert "the learner" in c  # most frequent subject (Zipf rank 1)

    def test_roundtrip_file(self, tmp_path):
        data = datagen.gen_corpus(5_000, seed=2)
        path = os.path.join(tmp_path, "c.bin")
        datagen.write_corpus(path, data)
        with open(path, "rb") as f:
            assert f.read(8) == datagen.TXT_MAGIC


class TestWeights:
    def test_roundtrip(self, tmp_path):
        w = np.random.default_rng(0).standard_normal(100).astype(np.float32)
        path = os.path.join(tmp_path, "w.bin")
        datagen.write_weights(path, w)
        w2 = datagen.read_weights(path)
        np.testing.assert_array_equal(w, w2)

    def test_rejects_bad_magic(self, tmp_path):
        path = os.path.join(tmp_path, "bad.bin")
        with open(path, "wb") as f:
            f.write(b"BADMAGIC" + b"\x00" * 12)
        with pytest.raises(AssertionError):
            datagen.read_weights(path)
