"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes and dtypes for every Pallas kernel against the
pure-jnp oracles in ``compile.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_linear, matmul, softmax_xent, softmax_xent_loss_grad
from compile.kernels import ref as kref
from compile.kernels.matmul import vmem_bytes

DIMS = st.integers(min_value=1, max_value=160)
SMALL_DIMS = st.integers(min_value=1, max_value=48)


def rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_f32(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, k, n)
    got = matmul(x, w)
    want = kref.matmul_ref(x, w)
    # K split across blocks accumulates in a different order than one
    # fused dot; allow a few ulps of f32 reassociation slack.
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=5e-5)


@settings(max_examples=10, deadline=None)
@given(m=SMALL_DIMS, k=SMALL_DIMS, n=SMALL_DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_bf16_accumulates_f32(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, k).astype(jnp.bfloat16)
    w = rand(rng, k, n).astype(jnp.bfloat16)
    got = matmul(x, w, out_dtype=jnp.float32)
    want = kref.matmul_ref(x, w, out_dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2
    )


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (32, 16, 64), (128, 128, 128)])
def test_matmul_block_shapes_equivalent(bm, bn, bk):
    rng = np.random.default_rng(0)
    x, w = rand(rng, 70, 90), rand(rng, 90, 50)
    got = matmul(x, w, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(got, kref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)


def test_matmul_rejects_bad_shapes():
    x = jnp.zeros((4, 5))
    with pytest.raises(ValueError):
        matmul(x, jnp.zeros((6, 3)))
    with pytest.raises(ValueError):
        matmul(jnp.zeros((4,)), jnp.zeros((4, 2)))


def test_matmul_grad_matches_ref_grad():
    rng = np.random.default_rng(3)
    x, w = rand(rng, 24, 40), rand(rng, 40, 16)

    def f_pallas(x, w):
        return jnp.sum(matmul(x, w) ** 2)

    def f_ref(x, w):
        return jnp.sum(kref.matmul_ref(x, w) ** 2)

    gx1, gw1 = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    gx2, gw2 = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx1, gx2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw1, gw2, rtol=1e-4, atol=1e-4)


def test_vmem_estimate_within_budget():
    # default 128³ f32 tiling must fit comfortably in 16 MiB VMEM
    assert vmem_bytes(128, 128, 128) < 1 << 20


@settings(max_examples=20, deadline=None)
@given(
    m=DIMS,
    k=DIMS,
    n=DIMS,
    act=st.sampled_from(["none", "relu", "gelu", "tanh"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    got = fused_linear(x, w, b, act=act)
    want = kref.fused_linear_ref(x, w, b, act=act)
    # K-blocked accumulation reorders float sums vs the fused reference;
    # allow a few ulps of f32 reassociation slack.
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=5e-5)


def test_fused_linear_rejects_unknown_act():
    with pytest.raises(ValueError):
        fused_linear(jnp.zeros((2, 2)), jnp.zeros((2, 2)), jnp.zeros(2), act="swish")


@pytest.mark.parametrize("act", ["none", "relu", "gelu", "tanh"])
def test_fused_linear_grads_match_ref(act):
    rng = np.random.default_rng(11)
    x, w, b = rand(rng, 20, 30), rand(rng, 30, 10), rand(rng, 10)

    def f_pallas(x, w, b):
        return jnp.sum(fused_linear(x, w, b, act=act) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(kref.fused_linear_ref(x, w, b, act=act) ** 2)

    g1 = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 130),
    c=st.integers(2, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_xent_matches_ref(b, c, seed):
    rng = np.random.default_rng(seed)
    logits = rand(rng, b, c) * 3.0
    labels = jnp.asarray(rng.integers(0, c, size=b).astype(np.int32))
    loss, grad = softmax_xent_loss_grad(logits, labels)
    loss_ref, grad_ref = kref.softmax_xent_loss_grad_ref(logits, labels)
    np.testing.assert_allclose(loss, loss_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(grad, grad_ref, rtol=1e-5, atol=1e-5)


def test_softmax_xent_custom_vjp_matches_autodiff_of_ref():
    rng = np.random.default_rng(5)
    logits = rand(rng, 32, 10)
    labels = jnp.asarray(rng.integers(0, 10, size=32).astype(np.int32))
    g1 = jax.grad(lambda z: softmax_xent(z, labels))(logits)
    g2 = jax.grad(lambda z: kref.softmax_xent_ref(z, labels))(logits)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-6)


def test_softmax_xent_extreme_logits_stable():
    # large logits must not overflow (max-subtraction in the kernel)
    logits = jnp.asarray([[1e4, -1e4, 0.0], [5e3, 5e3, 5e3]], jnp.float32)
    labels = jnp.asarray([0, 1], jnp.int32)
    loss, grad = softmax_xent_loss_grad(logits, labels)
    assert np.isfinite(np.asarray(loss)).all()
    assert np.isfinite(np.asarray(grad)).all()
    np.testing.assert_allclose(loss[0], 0.0, atol=1e-5)
