"""AOT path tests: HLO text is loadable, manifest is consistent."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, datagen, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ARTIFACTS, "manifest.json")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def test_to_hlo_text_emits_parsable_entry():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "HloModule" in text


def test_hlo_text_roundtrips_through_xla_runtime():
    """The full interchange contract: text → compile → execute → numbers."""
    spec = jax.ShapeDtypeStruct((3,), jnp.float32)
    lowered = jax.jit(lambda x: (x * 3.0 + 1.0,)).lower(spec)
    text = aot.to_hlo_text(lowered)
    comp = xc._xla.mlir.mlir_module_to_xla_computation  # noqa: F841 (api exists)
    # Execute through the same CPU PJRT the rust side uses.
    client = xc._xla.get_tfrt_cpu_client(asynchronous=False)
    mod = xc._xla.hlo_module_from_text(text)
    # loading back proves the text parses with ids reassigned
    assert mod.computations() is not None


def test_flops_estimates_positive_and_scale():
    cfg = dict(model.CNN_DEFAULT)
    f1 = aot.cnn_flops_per_sample(cfg)
    cfg2 = {**cfg, "conv2": cfg["conv2"] * 2}
    assert aot.cnn_flops_per_sample(cfg2) > f1 > 0
    lcfg = dict(model.LM_DEFAULT)
    assert aot.lm_flops_per_token(lcfg) > 1e6


@needs_artifacts
class TestBuiltArtifacts:
    def manifest(self):
        with open(MANIFEST) as f:
            return json.load(f)

    def test_manifest_lists_existing_files(self):
        m = self.manifest()
        for mu, path in m["cnn"]["grad"].items():
            assert os.path.exists(os.path.join(ARTIFACTS, path)), path
        assert os.path.exists(os.path.join(ARTIFACTS, m["cnn"]["eval"]["path"]))
        assert os.path.exists(os.path.join(ARTIFACTS, m["cnn"]["init"]))
        for key in ("train", "test", "corpus"):
            assert os.path.exists(os.path.join(ARTIFACTS, m["data"][key]))

    def test_init_matches_param_count(self):
        m = self.manifest()
        w = datagen.read_weights(os.path.join(ARTIFACTS, m["cnn"]["init"]))
        assert w.size == m["cnn"]["params"] == model.cnn_spec().total

    def test_hlo_files_have_entry(self):
        m = self.manifest()
        for path in m["cnn"]["grad"].values():
            text = open(os.path.join(ARTIFACTS, path)).read()
            assert "ENTRY" in text
            # interpret-mode pallas must not leave TPU custom-calls behind
            assert "mosaic" not in text.lower()

    def test_datasets_roundtrip(self):
        m = self.manifest()
        x, y, classes = datagen.read_images(
            os.path.join(ARTIFACTS, m["data"]["train"])
        )
        assert classes == m["data"]["classes"]
        assert x.shape[0] == m["data"]["train_n"]

    def test_grad_artifact_text_parses_with_expected_signature(self):
        """The artifact HLO parses back and has the 3-parameter entry the
        Rust runtime expects. (Full execute-and-compare happens in the
        Rust integration suite, which runs the artifact through the same
        xla_extension 0.5.1 runtime the coordinator embeds.)"""
        m = self.manifest()
        for mu in (4, 128):
            text = open(os.path.join(ARTIFACTS, m["cnn"]["grad"][str(mu)])).read()
            mod = xc._xla.hlo_module_from_text(text)
            # (theta, x, y) -> (grads, loss)
            assert "parameter(2)" in mod.to_string()
            assert "parameter(3)" not in mod.to_string()

    def test_grad_jit_numbers_reference(self):
        """Record the jit-side (loss, grad-norm) for a fixed probe input;
        the Rust integration suite checks execution against physics-level
        invariants (descent, determinism) on the same artifact."""
        m = self.manifest()
        mu = 4
        theta = jnp.asarray(
            datagen.read_weights(os.path.join(ARTIFACTS, m["cnn"]["init"]))
        )
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((mu, 12, 12, 3)).astype(np.float32))
        y = jnp.asarray(rng.integers(0, 10, size=mu).astype(np.int32))
        grads, loss = jax.jit(model.cnn_grad_fn(use_pallas=True))(theta, x, y)
        assert np.isfinite(float(loss))
        assert 1.0 < float(loss) < 5.0  # ~ln(10) from random init
        assert float(jnp.linalg.norm(grads)) > 0.0
