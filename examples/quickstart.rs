//! Quickstart: train the study CNN with 4 learners under 1-softsync and
//! print everything the framework measures.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use rudra::config::RunConfig;
use rudra::coordinator::protocol::Protocol;
use rudra::harness::sweep::Sweep;
use rudra::harness::Workspace;
use rudra::util::fmt_secs;

fn main() -> anyhow::Result<()> {
    // 1. Open the workspace: AOT artifacts (HLO text compiled onto the
    //    embedded PJRT CPU client) + datasets. Python is not involved.
    let ws = Workspace::open_default()?;
    println!(
        "loaded: {}-param CNN, {} train / {} test images\n",
        ws.manifest.cnn.params, ws.train.n, ws.test.n
    );

    // 2. Pick a (σ, μ, λ) point. 1-softsync keeps ⟨σ⟩ ≈ 1 regardless of
    //    λ — the paper's recommended protocol (§5.3).
    let cfg = RunConfig {
        protocol: Protocol::NSoftsync { n: 1 },
        mu: 16,
        lambda: 4,
        epochs: 5,
        ..RunConfig::default()
    };
    println!("training {}", cfg.label());

    // 3. Run it: real gradients through PJRT, time simulated at P775
    //    scale by the discrete-event cluster model.
    let mut sweep = Sweep::new(&ws, cfg.epochs);
    sweep.eval_each_epoch = true;
    let p = sweep.run_point(&cfg)?;

    for e in &p.epochs {
        println!(
            "  epoch {:>2}  train loss {:.4}  test err {:>6.2}%  (sim t = {})",
            e.epoch,
            e.train_loss,
            e.test_error_pct.unwrap_or(f64::NAN),
            fmt_secs(e.sim_time)
        );
    }
    println!(
        "\nfinal: test error {:.2}%  ⟨σ⟩ = {:.2}  max σ = {}  {} weight updates",
        p.test_error_pct, p.avg_staleness, p.max_staleness, p.updates
    );
    println!(
        "simulated wall-clock: {} (synthetic)  /  {} (paper CIFAR10 geometry, 140 epochs)",
        fmt_secs(p.sim_seconds),
        fmt_secs(p.paper_sim_seconds)
    );
    Ok(())
}
