//! Staleness anatomy demo (§3.1/§5.1): watch the vector clock work.
//!
//! Runs three protocols at λ = 8 on the synthetic CNN and prints, for
//! each, the per-update ⟨σ⟩ trace head, the staleness histogram, and the
//! learning rate the modulation policy actually applied — the paper's
//! quantification machinery made visible.
//!
//! ```text
//! cargo run --release --example staleness_demo
//! ```

use rudra::config::RunConfig;
use rudra::coordinator::protocol::Protocol;
use rudra::harness::sweep::Sweep;
use rudra::harness::Workspace;
use rudra::params::lr::Modulation;

fn main() -> anyhow::Result<()> {
    let ws = Workspace::open_default()?;
    let lambda = 8;

    for protocol in [
        Protocol::Hardsync,
        Protocol::NSoftsync { n: 1 },
        Protocol::NSoftsync { n: lambda },
        Protocol::Async,
    ] {
        let cfg = RunConfig {
            protocol,
            mu: 32,
            lambda,
            epochs: 2,
            modulation: Modulation::Auto,
            ..RunConfig::default()
        };
        let sweep = Sweep::new(&ws, cfg.epochs);
        let p = sweep.run_point(&cfg)?;

        println!("=== {} ===", cfg.label());
        println!(
            "  LR factor applied by modulation: ×{:.4}",
            cfg.lr_policy().factor(protocol, cfg.mu, lambda)
        );
        println!(
            "  ⟨σ⟩ = {:.2}   max σ = {}   (protocol's n = {})",
            p.avg_staleness,
            p.max_staleness,
            protocol.effective_n(lambda)
        );
        println!("  test error after {} epochs: {:.2}%", cfg.epochs, p.test_error_pct);
        println!();
    }

    println!("observations (the paper's §5.1):");
    println!("  * hardsync: σ ≡ 0 — the barrier removes staleness entirely");
    println!("  * 1-softsync: ⟨σ⟩ ≈ 1 independent of λ");
    println!("  * λ-softsync / async: ⟨σ⟩ ≈ λ, bounded by ≈ 2λ");
    Ok(())
}
