//! End-to-end validation driver (DESIGN.md §6): train the transformer
//! byte-LM through the FULL stack for a few hundred steps and log the
//! loss curve — proving all three layers compose:
//!
//!   L1 Pallas matmul/fused-linear/softmax-xent kernels
//!     → lowered inside the L2 JAX grad graph (AOT, HLO text)
//!       → executed by the PJRT runtime embedded in
//!         → the L3 Rust parameter server (1-softsync, λ learners,
//!           staleness-modulated LR, virtual-time engine).
//!
//! The run is recorded in EXPERIMENTS.md. Steps/λ are configurable:
//!
//! ```text
//! cargo run --release --example transformer_e2e -- --steps 300 --lambda 4
//! ```

use rudra::coordinator::engine_sim::{run_sim, Evaluator, SimConfig};
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::harness::providers::LmProvider;
use rudra::harness::Workspace;
use rudra::netsim::cluster::ClusterSpec;
use rudra::netsim::cost::{LearnerCompute, ModelCost};
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::stats::TokenEvaluator;
use rudra::util::cli::Args;
use rudra::util::fmt_secs;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let steps = args.usize_or("steps", 300)?;
    let lambda = args.usize_or("lambda", 4)?;
    let lr0 = args.f64_or("lr", 3e-3)?;

    let ws = Workspace::open_default()?;
    let lm = ws.manifest.lm.as_ref().expect("LM artifacts (run `make artifacts`)");
    let (batch, seq) = (ws.manifest.lm_batch, ws.manifest.lm_seq);
    println!(
        "transformer e2e: {} params, batch {batch} × seq {seq}, λ = {lambda}, {steps} steps",
        lm.params
    );
    println!("protocol: 1-softsync + α₀/⟨σ⟩ modulation + Adam-free momentum SGD\n");

    let grad = ws.lm_grad()?;
    let eval = ws.lm_eval()?;
    let mut provider = LmProvider::new(&grad, &ws.corpus, batch, seq, lambda, 99);
    let mut evaluator = TokenEvaluator::new(&eval, &ws.corpus, batch, seq, 4)?;

    // Cost model of the actual LM (for the virtual clock): tokens/sample.
    let tokens_per_batch = (batch * seq) as f64;
    let model_cost = ModelCost {
        name: "byte-lm",
        flops_per_sample: lm.flops * tokens_per_batch / batch as f64,
        bytes: (lm.params * 4) as f64,
        samples_per_epoch: u64::MAX, // epochs unused; we cap by updates
    };

    let start = std::time::Instant::now();
    let theta0 = ws.lm_init()?;
    let (init_loss, init_err) = evaluator.eval(&theta0)?;
    println!("step 0: held-out loss {init_loss:.4} ({init_err:.1}% next-byte error)");

    let cfg = SimConfig {
        protocol: Protocol::NSoftsync { n: 1 },
        arch: Arch::Base,
        mu: batch,
        lambda,
        epochs: usize::MAX >> 1,
        seed: 7,
        cluster: ClusterSpec::p775(),
        compute: LearnerCompute::p775(),
        model: model_cost,
        eval_each_epoch: false,
        max_updates: Some(steps as u64),
    };
    let optimizer = Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, theta0.len());
    let lr = LrPolicy::new(Schedule::constant(lr0), Modulation::Auto, batch);
    let r = run_sim(&cfg, theta0, optimizer, lr, Some(&mut provider), Some(&mut evaluator))?;

    let theta = r.theta.expect("weights");
    let (final_loss, final_err) = evaluator.eval(&theta)?;
    println!(
        "step {}: held-out loss {final_loss:.4} ({final_err:.1}% next-byte error)",
        r.updates
    );
    println!(
        "\ntrain loss (mean, last window): {:.4}   ⟨σ⟩ = {:.2}   max σ = {}",
        r.final_train_loss,
        r.staleness.overall_avg(),
        r.staleness.max
    );
    println!(
        "wall-clock: {} real on this host; {} simulated at P775 scale",
        fmt_secs(start.elapsed().as_secs_f64()),
        fmt_secs(r.sim_seconds)
    );
    anyhow::ensure!(
        final_loss < init_loss - 0.3,
        "e2e training must reduce held-out loss materially: {init_loss:.3} -> {final_loss:.3}"
    );
    println!("\nloss fell {init_loss:.3} → {final_loss:.3}: all three layers compose ✓");
    Ok(())
}
