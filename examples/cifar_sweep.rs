//! The paper's central experiment in miniature: a (σ, μ, λ) sweep over
//! the synthetic CIFAR-style benchmark, printing the tradeoff table that
//! Figures 6/7 plot — error vs (simulated) time as μ and λ vary.
//!
//! ```text
//! cargo run --release --example cifar_sweep               # reduced grid
//! RUDRA_FULL=1 cargo run --release --example cifar_sweep  # paper grid
//! ```

use rudra::coordinator::protocol::Protocol;
use rudra::harness::paper;
use rudra::harness::sweep::Sweep;
use rudra::harness::Workspace;
use rudra::stats::table::{f, pct, Table};
use rudra::util::fmt_secs;

fn main() -> anyhow::Result<()> {
    let ws = Workspace::open_default()?;
    let (mus, lambdas, epochs) = paper::grid_axes();
    println!(
        "sweeping μ ∈ {mus:?} × λ ∈ {lambdas:?} for {epochs} epochs under 3 protocols\n"
    );

    let families: [(&str, fn(usize) -> Protocol); 3] = [
        ("hardsync", |_| Protocol::Hardsync),
        ("1-softsync", |_| Protocol::NSoftsync { n: 1 }),
        ("λ-softsync", |l| Protocol::NSoftsync { n: l }),
    ];

    for (name, proto_of) in families {
        println!("--- {name} ---");
        let sweep = Sweep::new(&ws, epochs);
        let results = sweep.run_grid(&mus, &lambdas, proto_of)?;
        let mut t =
            Table::new(&["μ", "λ", "⟨σ⟩", "test err", "sim time (paper geometry)"]);
        for r in &results {
            t.row(vec![
                r.mu.to_string(),
                r.lambda.to_string(),
                f(r.avg_staleness, 1),
                pct(r.test_error_pct),
                fmt_secs(r.paper_sim_seconds),
            ]);
        }
        t.print();
        println!();
    }

    println!("reading the tables (the paper's Figures 6–7):");
    println!("  * fixed μ, growing λ: time ↓, error ↑");
    println!("  * fixed λ, shrinking μ: error recovers, time partially sacrificed");
    println!("  * small μ stays accurate even at ⟨σ⟩ ≈ λ (staleness immunity)");
    Ok(())
}
