//! ImageNet-scale architecture study (paper §5.5) — timing-only
//! simulation of the 289 MB AlexNet workload at the paper's exact
//! geometry, across the Rudra-base / adv / adv* ladder plus the λ and μ
//! scaling rules around it.
//!
//! ```text
//! cargo run --release --example imagenet_sim
//! ```

use rudra::coordinator::engine_sim::{run_sim, SimConfig};
use rudra::coordinator::protocol::Protocol;
use rudra::coordinator::tree::Arch;
use rudra::netsim::cost::ModelCost;
use rudra::params::lr::{LrPolicy, Modulation, Schedule};
use rudra::params::optimizer::{Optimizer, OptimizerKind};
use rudra::params::FlatVec;
use rudra::stats::table::{f, Table};

fn minutes_per_epoch(protocol: Protocol, arch: Arch, mu: usize, lambda: usize) -> f64 {
    let mut cfg = SimConfig::paper(protocol, arch, mu, lambda, 1, ModelCost::imagenet());
    cfg.seed = 3;
    let r = run_sim(
        &cfg,
        FlatVec::zeros(0),
        Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
        LrPolicy::new(Schedule::constant(0.01), Modulation::Auto, 128),
        None,
        None,
    )
    .expect("sim");
    r.sim_seconds / 60.0
}

fn main() -> anyhow::Result<()> {
    println!("ImageNet workload: 289 MB model, 1.2M images/epoch (simulated P775)\n");

    // The baseline anchor: paper says 54 h/epoch at (μ=256, λ=1).
    let base = minutes_per_epoch(Protocol::Hardsync, Arch::Base, 256, 1);
    println!("baseline (μ=256, λ=1): {:.1} h/epoch (paper: 54 h/epoch)\n", base / 60.0);

    // The Table-4 ladder.
    let mut t = Table::new(&["config", "μ", "λ", "min/epoch (sim)", "paper min/epoch"]);
    let ladder: [(&str, Protocol, Arch, usize, usize, f64); 4] = [
        ("base-hardsync", Protocol::Hardsync, Arch::Base, 16, 18, 330.0),
        ("base-softsync", Protocol::NSoftsync { n: 1 }, Arch::Base, 16, 18, 270.0),
        ("adv-softsync", Protocol::NSoftsync { n: 1 }, Arch::Adv, 4, 54, 212.0),
        ("adv*-softsync", Protocol::NSoftsync { n: 1 }, Arch::AdvStar, 4, 54, 125.0),
    ];
    for (name, protocol, arch, mu, lambda, paper_min) in ladder {
        let m = minutes_per_epoch(protocol, arch, mu, lambda);
        t.row(vec![
            name.to_string(),
            mu.to_string(),
            lambda.to_string(),
            f(m, 0),
            f(paper_min, 0),
        ]);
    }
    t.print();

    // λ-scaling under adv*: where does adding learners stop helping?
    println!("\nadv*-softsync scaling at μ=4:");
    let mut t2 = Table::new(&["λ", "min/epoch (sim)", "speed-up vs λ=18"]);
    let t18 = minutes_per_epoch(Protocol::NSoftsync { n: 1 }, Arch::AdvStar, 4, 18);
    for lambda in [18usize, 36, 54, 108] {
        let m = minutes_per_epoch(Protocol::NSoftsync { n: 1 }, Arch::AdvStar, 4, lambda);
        t2.row(vec![lambda.to_string(), f(m, 0), f(t18 / m, 2)]);
    }
    t2.print();

    println!(
        "\nthe paper's rule (§5.5): scaling λ up must be paired with scaling μ down\n\
         (their μ=8, λ=54 run trained fast but produced >50% top-1 error —\n\
         runtime alone is not the objective)."
    );
    Ok(())
}
